//! Windowed pollution telemetry: the controller's view of how the cache is
//! doing *right now*, computed incrementally from the hierarchy's cumulative
//! counters plus a per-window reuse-distance sketch.
//!
//! The simulator's [`crate::metrics::MetricsReport`] is an end-of-run
//! aggregate; drift detection needs a *stream* of short-horizon samples.
//! [`Telemetry`] differentiates the hierarchy's monotone counters at window
//! boundaries (one subtraction per counter — no per-access cost beyond the
//! reuse sketch's map touch), yielding one [`WindowStats`] per
//! `window_accesses` simulated accesses.

use crate::mem::Hierarchy;
use crate::util::hash::FastMap;
use crate::util::json::Json;

/// Snapshot of the cumulative counters the telemetry differentiates.
#[derive(Debug, Clone, Copy, Default)]
struct CounterSnapshot {
    accesses: u64,
    demand_accesses: u64,
    demand_hits: u64,
    demand_misses: u64,
    prefetch_fills: u64,
    prefetch_useful: u64,
    dead_prefetch_evictions: u64,
    demand_evicted_by_prefetch: u64,
}

impl CounterSnapshot {
    fn of(hier: &Hierarchy) -> Self {
        let l2 = &hier.l2.stats;
        Self {
            accesses: hier.accesses,
            demand_accesses: l2.demand_accesses,
            demand_hits: l2.demand_hits,
            demand_misses: l2.demand_misses,
            prefetch_fills: l2.prefetch_fills,
            prefetch_useful: l2.prefetch_useful,
            dead_prefetch_evictions: l2.dead_prefetch_evictions,
            demand_evicted_by_prefetch: l2.demand_evicted_by_prefetch,
        }
    }
}

/// One telemetry window: L2-centric health metrics over the last
/// `window_accesses` accesses (not cumulative).
#[derive(Debug, Clone, Copy)]
pub struct WindowStats {
    /// 0-based window index.
    pub index: u64,
    /// Engine accesses covered by this window.
    pub accesses: u64,
    /// L2 demand accesses in the window.
    pub l2_demand: u64,
    /// L2 demand hit rate in the window.
    pub hit_rate: f64,
    /// Dead-block/pollution rate: dead prefetch evictions (+ demand lines
    /// evicted by prefetches) per L2 fill-side event in the window.
    pub pollution: f64,
    /// Useful prefetches per prefetch fill in the window.
    pub prefetch_accuracy: f64,
    /// Median log2 reuse distance observed in the window (the sketch's
    /// p50 bucket); `u8::MAX` when the window saw no reuse at all.
    pub reuse_p50_log2: u8,
}

impl WindowStats {
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("index", Json::Num(self.index as f64)),
            ("accesses", Json::Num(self.accesses as f64)),
            ("l2_demand", Json::Num(self.l2_demand as f64)),
            ("hit_rate", Json::Num(self.hit_rate)),
            ("pollution", Json::Num(self.pollution)),
            ("prefetch_accuracy", Json::Num(self.prefetch_accuracy)),
            ("reuse_p50_log2", Json::Num(self.reuse_p50_log2 as f64)),
        ])
    }

    /// Inverse of [`Self::to_json`] (report-store rehydration). Numeric
    /// `null` decodes as NaN, matching the serializer's non-finite → `null`
    /// convention.
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let f = |key: &str| -> anyhow::Result<f64> {
            match j.req(key)? {
                Json::Null => Ok(f64::NAN),
                v => v.as_f64().ok_or_else(|| anyhow::anyhow!("window.{key}: expected number")),
            }
        };
        let u = |key: &str| -> anyhow::Result<u64> {
            let v = f(key)?;
            if v.is_finite() && v >= 0.0 && v.fract() == 0.0 {
                Ok(v as u64)
            } else {
                anyhow::bail!("window.{key}: expected non-negative integer")
            }
        };
        Ok(Self {
            index: u("index")?,
            accesses: u("accesses")?,
            l2_demand: u("l2_demand")?,
            hit_rate: f("hit_rate")?,
            pollution: f("pollution")?,
            prefetch_accuracy: f("prefetch_accuracy")?,
            reuse_p50_log2: u("reuse_p50_log2")?.min(u8::MAX as u64) as u8,
        })
    }
}

/// Bounded last-touch map + log2-bucketed histogram of line reuse
/// distances, reset each window. Distances are measured in accesses.
pub struct ReuseSketch {
    last: FastMap<u64, u64>,
    capacity: usize,
    hist: [u64; 33],
}

impl ReuseSketch {
    pub fn new(capacity: usize) -> Self {
        Self { last: FastMap::default(), capacity: capacity.max(1024), hist: [0; 33] }
    }

    /// Record one touch of `line` at access position `pos` using the
    /// sketch's own last-touch map. Runs that already maintain a shared
    /// [`super::LastTouch`] should call [`record_prev`](Self::record_prev)
    /// instead and skip this map entirely.
    pub fn touch(&mut self, pos: u64, line: u64) {
        if self.last.len() >= self.capacity {
            // Cheap deterministic wholesale aging (same idiom as the
            // hierarchy's utility cache).
            self.last.clear();
        }
        let prev = self.last.insert(line, pos);
        self.record_prev(prev, pos);
    }

    /// Histogram a reuse distance given the line's previous touch position
    /// (from a shared last-touch map); `None` = first observed touch.
    pub fn record_prev(&mut self, prev: Option<u64>, pos: u64) {
        if let Some(prev) = prev {
            let dist = pos.saturating_sub(prev).max(1);
            // log2 bucket: 1 → 0, 2..3 → 1, 4..7 → 2, ... capped at 32.
            let bucket = (63 - dist.leading_zeros() as usize).min(32);
            self.hist[bucket] += 1;
        }
    }

    /// Median bucket of the current histogram; `None` when empty.
    pub fn p50_bucket(&self) -> Option<u8> {
        let total: u64 = self.hist.iter().sum();
        if total == 0 {
            return None;
        }
        let mut acc = 0u64;
        for (i, &c) in self.hist.iter().enumerate() {
            acc += c;
            if acc * 2 >= total {
                return Some(i as u8);
            }
        }
        Some(32)
    }

    /// Reset the histogram for the next window (the last-touch map is kept —
    /// reuse spanning a window boundary is still reuse).
    pub fn reset_window(&mut self) {
        self.hist = [0; 33];
    }

    /// Fold another sketch's histogram into this one (last-touch maps stay
    /// separate). The serve engine keeps one sketch per (worker, tenant) so
    /// positions stay per-worker-monotone, then absorbs them into a
    /// per-tenant sketch at each arbitration window boundary.
    pub fn absorb(&mut self, other: &ReuseSketch) {
        for (a, b) in self.hist.iter_mut().zip(other.hist.iter()) {
            *a += *b;
        }
    }
}

/// Incremental window telemetry over a running [`Hierarchy`].
pub struct Telemetry {
    prev: CounterSnapshot,
    sketch: ReuseSketch,
    windows: u64,
}

impl Telemetry {
    pub fn new() -> Self {
        Self { prev: CounterSnapshot::default(), sketch: ReuseSketch::new(1 << 16), windows: 0 }
    }

    /// Per-access hook (cheap: one bounded map insert).
    pub fn touch(&mut self, pos: u64, line: u64) {
        self.sketch.touch(pos, line);
    }

    /// Per-access hook for callers that maintain a shared
    /// [`super::LastTouch`] map: records only the histogram update, no map
    /// work.
    pub fn record_reuse(&mut self, prev: Option<u64>, pos: u64) {
        self.sketch.record_prev(prev, pos);
    }

    /// Windows harvested so far.
    pub fn windows(&self) -> u64 {
        self.windows
    }

    /// Close the current window against the hierarchy's cumulative counters
    /// and return its stats.
    pub fn harvest(&mut self, hier: &Hierarchy) -> WindowStats {
        let now = CounterSnapshot::of(hier);
        let p = self.prev;
        let demand = now.demand_accesses - p.demand_accesses;
        let hits = now.demand_hits - p.demand_hits;
        let pf_fills = now.prefetch_fills - p.prefetch_fills;
        // Fill-side events this window (same normalization as
        // `CacheStats::pollution_ratio`): demand-miss fills + prefetch
        // fills. Normalizing by prefetch fills alone would let a window
        // with few fills but carried-over dead evictions spike unboundedly.
        let all_fills = (now.demand_misses - p.demand_misses) + pf_fills;
        let useful = now.prefetch_useful - p.prefetch_useful;
        let dead = (now.dead_prefetch_evictions - p.dead_prefetch_evictions)
            + (now.demand_evicted_by_prefetch - p.demand_evicted_by_prefetch);
        let stats = WindowStats {
            index: self.windows,
            accesses: now.accesses - p.accesses,
            l2_demand: demand,
            hit_rate: hits as f64 / demand.max(1) as f64,
            pollution: dead as f64 / all_fills.max(1) as f64,
            prefetch_accuracy: useful as f64 / pf_fills.max(1) as f64,
            reuse_p50_log2: self.sketch.p50_bucket().unwrap_or(u8::MAX),
        };
        self.prev = now;
        self.sketch.reset_window();
        self.windows += 1;
        stats
    }
}

impl Default for Telemetry {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::HierarchyConfig;
    use crate::policy::AccessMeta;
    use crate::trace::{GeneratorConfig, TraceGenerator};

    #[test]
    fn reuse_sketch_buckets_distances() {
        let mut s = ReuseSketch::new(4096);
        assert_eq!(s.p50_bucket(), None);
        // Line 1 touched at 0 and 1 → distance 1 → bucket 0.
        s.touch(0, 1);
        s.touch(1, 1);
        assert_eq!(s.p50_bucket(), Some(0));
        // Line 2 at distance 8 → bucket 3 shifts the median up.
        s.touch(10, 2);
        s.touch(18, 2);
        s.touch(26, 2);
        assert_eq!(s.p50_bucket(), Some(3));
        s.reset_window();
        assert_eq!(s.p50_bucket(), None);
    }

    #[test]
    fn windows_differentiate_cumulative_counters() {
        let mut cfg = HierarchyConfig::scaled();
        cfg.prefetcher = "nextline".into();
        let mut h = Hierarchy::new(cfg, "lru");
        let mut gen = TraceGenerator::new(GeneratorConfig::tiny(5));
        let mut t = Telemetry::new();
        let mut total_demand = 0u64;
        for w in 0..4u64 {
            for i in 0..10_000u64 {
                let a = gen.next_access();
                let meta = AccessMeta::demand(a.line(), a.pc, a.kind);
                h.access(&a, &meta);
                t.touch(w * 10_000 + i, a.line());
            }
            let ws = t.harvest(&h);
            assert_eq!(ws.index, w);
            assert_eq!(ws.accesses, 10_000);
            assert!(ws.hit_rate > 0.0 && ws.hit_rate <= 1.0, "window {w}: {}", ws.hit_rate);
            assert!(ws.pollution >= 0.0);
            total_demand += ws.l2_demand;
        }
        // Window deltas must sum back to the cumulative counter.
        assert_eq!(total_demand, h.l2.stats.demand_accesses);
        assert_eq!(t.windows(), 4);
    }
}
