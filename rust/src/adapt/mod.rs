//! Online adaptive-control subsystem: the piece that makes "adaptive cache
//! pollution control" *adaptive at runtime* rather than only at training
//! time.
//!
//! - [`telemetry`] — windowed pollution telemetry (per-window hit rate,
//!   dead-block/pollution rate, prefetch accuracy, reuse-distance sketch)
//!   computed incrementally alongside [`crate::sim::Engine::step`];
//! - [`drift`] — a deterministic two-sided Page–Hinkley phase/drift
//!   detector over the telemetry stream;
//! - [`learner`] — the §3.4 replay-buffer [`OnlineLearner`], lifted out of
//!   the simulator and generalized over any [`crate::predictor::PredictorBox`];
//! - [`controller`] — the [`AdaptiveController`] closing the loop: on
//!   drift it fine-tunes a trainable predictor from the replay buffer and
//!   hot-swaps the weights behind a versioned handle, or throttles
//!   predictions down to policy-default insertion when no trainable model
//!   exists / confidence collapses (LLaMCAT-style back-off).
//!
//! Consumers: the [`crate::api::Runner`] (adaptive specs — `acpc adapt`,
//! `acpc sweep --predictor adaptive`, `acpc run`) and the serving
//! coordinator's workers (per-worker throttle controllers). The
//! controller-ON-vs-OFF comparison harness is [`crate::api::run_compare`];
//! this module keeps its result type, [`CompareOutput`].

pub mod controller;
pub mod drift;
pub mod last_touch;
pub mod learner;
pub mod telemetry;

pub use controller::{
    AdaptationAction, AdaptationEvent, AdaptiveController, ControlDecision, ControllerConfig,
    ControllerSummary, PredictorAccess,
};
pub use drift::{Drift, PageHinkley};
pub use last_touch::LastTouch;
pub use learner::OnlineLearner;
pub use telemetry::{ReuseSketch, Telemetry, WindowStats};

use crate::sim::SimResult;
use crate::util::json::Json;

/// Result of one controller-on vs controller-off replay of the same
/// workload and seed ([`crate::api::run_compare`] / `acpc adapt`).
#[derive(Debug, Clone)]
pub struct CompareOutput {
    pub baseline: SimResult,
    pub adaptive: SimResult,
    pub summary: ControllerSummary,
    /// Provenance of what actually ran in each arm (e.g.
    /// `heuristic(fallback)` when TCN artifacts were absent) — the spec
    /// records what was *requested*, these record what *executed*.
    pub predictor_effective_baseline: String,
    pub predictor_effective_adaptive: String,
}

impl CompareOutput {
    /// L2 hit-rate delta (adaptive − baseline), in absolute rate units.
    pub fn hit_rate_delta(&self) -> f64 {
        self.adaptive.report.l2_hit_rate - self.baseline.report.l2_hit_rate
    }

    /// Pollution-ratio delta (adaptive − baseline).
    pub fn pollution_delta(&self) -> f64 {
        self.adaptive.report.l2_pollution_ratio - self.baseline.report.l2_pollution_ratio
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("baseline", self.baseline.report.to_json()),
            ("adaptive", self.adaptive.report.to_json()),
            (
                "predictor_effective",
                Json::from_pairs(vec![
                    ("baseline", Json::Str(self.predictor_effective_baseline.clone())),
                    ("adaptive", Json::Str(self.predictor_effective_adaptive.clone())),
                ]),
            ),
            ("adaptation", self.summary.to_json()),
            (
                "deltas",
                Json::from_pairs(vec![
                    ("hit_rate", Json::Num(self.hit_rate_delta())),
                    ("pollution", Json::Num(self.pollution_delta())),
                    ("amat", Json::Num(self.adaptive.report.amat - self.baseline.report.amat)),
                ]),
            ),
        ])
    }
}

// (`run_compare` / `run_compare_sharded` moved behind the one front door:
// see `crate::api::run_compare`, which replays the spec's run through two
// `Runner`s — adaptive arm and stripped baseline — on identical seeds.)
