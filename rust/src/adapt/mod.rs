//! Online adaptive-control subsystem: the piece that makes "adaptive cache
//! pollution control" *adaptive at runtime* rather than only at training
//! time.
//!
//! - [`telemetry`] — windowed pollution telemetry (per-window hit rate,
//!   dead-block/pollution rate, prefetch accuracy, reuse-distance sketch)
//!   computed incrementally alongside [`crate::sim::Engine::step`];
//! - [`drift`] — a deterministic two-sided Page–Hinkley phase/drift
//!   detector over the telemetry stream;
//! - [`learner`] — the §3.4 replay-buffer [`OnlineLearner`], lifted out of
//!   the simulator and generalized over any [`crate::predictor::PredictorBox`];
//! - [`controller`] — the [`AdaptiveController`] closing the loop: on
//!   drift it fine-tunes a trainable predictor from the replay buffer and
//!   hot-swaps the weights behind a versioned handle, or throttles
//!   predictions down to policy-default insertion when no trainable model
//!   exists / confidence collapses (LLaMCAT-style back-off).
//!
//! Consumers: `sim::run_workload_adaptive` (batch runs + `acpc adapt`),
//! `sim::sweep` (`--predictor adaptive` cells) and the serving
//! coordinator's workers (per-worker throttle controllers).

pub mod controller;
pub mod drift;
pub mod last_touch;
pub mod learner;
pub mod telemetry;

pub use controller::{
    AdaptationAction, AdaptationEvent, AdaptiveController, ControlDecision, ControllerConfig,
    ControllerSummary, PredictorAccess,
};
pub use drift::{Drift, PageHinkley};
pub use last_touch::LastTouch;
pub use learner::OnlineLearner;
pub use telemetry::{ReuseSketch, Telemetry, WindowStats};

use crate::config::ExperimentConfig;
use crate::predictor::PredictorBox;
use crate::sim::SimResult;
use crate::util::json::Json;

/// Result of one controller-on vs controller-off replay of the same
/// workload and seed (`acpc adapt`).
#[derive(Debug, Clone)]
pub struct CompareOutput {
    pub baseline: SimResult,
    pub adaptive: SimResult,
    pub summary: ControllerSummary,
}

impl CompareOutput {
    /// L2 hit-rate delta (adaptive − baseline), in absolute rate units.
    pub fn hit_rate_delta(&self) -> f64 {
        self.adaptive.report.l2_hit_rate - self.baseline.report.l2_hit_rate
    }

    /// Pollution-ratio delta (adaptive − baseline).
    pub fn pollution_delta(&self) -> f64 {
        self.adaptive.report.l2_pollution_ratio - self.baseline.report.l2_pollution_ratio
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("baseline", self.baseline.report.to_json()),
            ("adaptive", self.adaptive.report.to_json()),
            ("adaptation", self.summary.to_json()),
            (
                "deltas",
                Json::from_pairs(vec![
                    ("hit_rate", Json::Num(self.hit_rate_delta())),
                    ("pollution", Json::Num(self.pollution_delta())),
                    ("amat", Json::Num(self.adaptive.report.amat - self.baseline.report.amat)),
                ]),
            ),
        ])
    }
}

/// Replay the workload `cfg` describes twice with identical seeds — once
/// without and once with the adaptive controller — and report both runs
/// plus the controller's event log. `mk_predictor` is invoked once per run
/// so each replay gets a fresh predictor (fresh weights for trainable
/// ones).
pub fn run_compare(
    cfg: &ExperimentConfig,
    ccfg: &ControllerConfig,
    mut mk_predictor: impl FnMut() -> PredictorBox,
) -> CompareOutput {
    let mut base_pred = mk_predictor();
    let mut base_workload = cfg.workload();
    let baseline = crate::sim::run_workload(cfg, base_workload.as_mut(), &mut base_pred);

    let mut adapt_pred = mk_predictor();
    let mut controller = AdaptiveController::new(ccfg.clone());
    let mut adapt_workload = cfg.workload();
    let adaptive = crate::sim::run_workload_adaptive(
        cfg,
        adapt_workload.as_mut(),
        &mut adapt_pred,
        Some(&mut controller),
    );
    CompareOutput { baseline, adaptive, summary: controller.into_summary() }
}

/// [`run_compare`] with both arms split across `shards` set partitions
/// (`crate::sim::shard`). `mk_predictor` runs once per shard *inside* each
/// shard thread; the adaptive arm runs one controller per shard and the
/// reported summary is their [`ControllerSummary::merge`].
pub fn run_compare_sharded(
    cfg: &ExperimentConfig,
    ccfg: &ControllerConfig,
    shards: usize,
    mk_predictor: &(dyn Fn(usize) -> PredictorBox + Sync),
) -> anyhow::Result<CompareOutput> {
    let mut base_workload = cfg.workload();
    let baseline =
        crate::sim::run_workload_sharded(cfg, base_workload.as_mut(), shards, mk_predictor, None)?;
    let mut adapt_workload = cfg.workload();
    let adaptive = crate::sim::run_workload_sharded(
        cfg,
        adapt_workload.as_mut(),
        shards,
        mk_predictor,
        Some(ccfg),
    )?;
    Ok(CompareOutput {
        baseline: baseline.result,
        adaptive: adaptive.result,
        summary: ControllerSummary::merge(adaptive.controllers),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, PredictorKind};
    use crate::predictor::HeuristicPredictor;

    #[test]
    fn compare_runs_both_arms_on_one_seed() {
        let mut cfg =
            ExperimentConfig::for_scenario("multi-tenant-mix", "acpc", PredictorKind::Heuristic, 42)
                .unwrap();
        cfg.accesses = 60_000;
        let mut ccfg = ControllerConfig::quick();
        ccfg.window_accesses = 2048;
        let out = run_compare(&cfg, &ccfg, || PredictorBox::Heuristic(HeuristicPredictor));
        assert_eq!(out.baseline.report.accesses, 60_000);
        assert_eq!(out.adaptive.report.accesses, 60_000);
        assert!(out.summary.windows_observed > 0);
        let j = out.to_json();
        for key in ["baseline", "adaptive", "adaptation", "deltas"] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
        assert!(j.get("deltas").unwrap().get("hit_rate").unwrap().as_f64().is_some());
    }
}
