//! The unified per-line last-touch map for adaptive runs.
//!
//! Before this module, an adaptive run tracked line→last-position *twice*
//! per access: once in the telemetry [`super::ReuseSketch`] (reuse-distance
//! histogram) and once in the replay [`super::OnlineLearner`] (label
//! resolution). [`LastTouch`] is the single shared structure: the
//! [`super::AdaptiveController`] touches it once per access, feeds the
//! returned previous position to the telemetry sketch, and lends the map to
//! the learner for labeling — halving the per-access map work when both
//! consumers are active.

use crate::util::hash::FastMap;

/// Bounded line → last-touch-position map with deterministic aging.
pub struct LastTouch {
    map: FastMap<u64, u64>,
    capacity: usize,
    /// Retention horizon (accesses): on overflow, entries older than this
    /// are swept. Consumers that only need distances/labels up to their own
    /// horizon lose nothing as long as `horizon` covers it.
    horizon: u64,
}

impl LastTouch {
    pub fn new(capacity: usize, horizon: u64) -> Self {
        Self { map: FastMap::default(), capacity: capacity.max(1024), horizon: horizon.max(1) }
    }

    /// Record a touch of `line` at position `pos`; returns the previous
    /// touch position if the line was tracked.
    pub fn touch(&mut self, pos: u64, line: u64) -> Option<u64> {
        if self.map.len() >= self.capacity {
            let horizon = self.horizon;
            self.map.retain(|_, &mut t| pos.saturating_sub(t) <= horizon);
            // Pathological case (more live lines within the horizon than
            // capacity): deterministic wholesale aging, same idiom as the
            // hierarchy's utility cache.
            if self.map.len() >= self.capacity {
                self.map.clear();
            }
        }
        self.map.insert(line, pos)
    }

    /// Last touch position of `line`, if tracked.
    pub fn last(&self, line: u64) -> Option<u64> {
        self.map.get(&line).copied()
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_previous_positions() {
        let mut lt = LastTouch::new(2048, 100);
        assert_eq!(lt.touch(5, 42), None);
        assert_eq!(lt.touch(9, 42), Some(5));
        assert_eq!(lt.last(42), Some(9));
        assert_eq!(lt.last(7), None);
    }

    #[test]
    fn overflow_sweeps_stale_entries() {
        let mut lt = LastTouch::new(1024, 64);
        // Fill beyond capacity with strictly aging entries.
        for i in 0..2000u64 {
            lt.touch(i, i);
        }
        assert!(lt.len() <= 1024, "{}", lt.len());
        // Recent entries survive the sweep.
        assert_eq!(lt.last(1999), Some(1999));
    }
}
