//! Deterministic phase/drift detection over the telemetry stream.
//!
//! A two-sided Page–Hinkley test over a scalar signal (the controller feeds
//! it the per-window L2 hit rate): the test tracks the running mean and two
//! one-sided cumulative deviations; when either exceeds `lambda` the signal
//! has shifted and a [`Drift`] fires. Thresholds come from
//! [`crate::adapt::ControllerConfig`] — the detector itself has no
//! randomness, so a fixed access stream yields a fixed drift sequence
//! regardless of thread count or wall clock.

/// Direction of a detected mean shift.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Drift {
    /// The signal dropped (hit rate collapsing — the interesting case).
    Down,
    /// The signal rose (e.g. recovery after a phase ends).
    Up,
}

/// Two-sided Page–Hinkley mean-shift detector.
#[derive(Debug, Clone)]
pub struct PageHinkley {
    /// Magnitude tolerance: deviations below `delta` are treated as noise.
    delta: f64,
    /// Detection threshold on the cumulative deviation.
    lambda: f64,
    /// Samples required before a detection may fire.
    min_samples: u64,
    n: u64,
    mean: f64,
    /// Cumulative evidence of a downward / upward shift (CUSUM form).
    m_down: f64,
    m_up: f64,
}

impl PageHinkley {
    pub fn new(delta: f64, lambda: f64, min_samples: u64) -> Self {
        Self { delta, lambda, min_samples, n: 0, mean: 0.0, m_down: 0.0, m_up: 0.0 }
    }

    /// Samples absorbed since the last reset.
    pub fn samples(&self) -> u64 {
        self.n
    }

    /// Running mean of the current regime.
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Feed one sample; `Some(direction)` when a shift is detected. The
    /// detector resets itself after a detection (the new regime becomes the
    /// reference).
    pub fn update(&mut self, x: f64) -> Option<Drift> {
        self.n += 1;
        self.mean += (x - self.mean) / self.n as f64;
        self.m_down = (self.m_down + (self.mean - x - self.delta)).max(0.0);
        self.m_up = (self.m_up + (x - self.mean - self.delta)).max(0.0);
        if self.n < self.min_samples {
            return None;
        }
        let drift = if self.m_down > self.lambda {
            Some(Drift::Down)
        } else if self.m_up > self.lambda {
            Some(Drift::Up)
        } else {
            None
        };
        if drift.is_some() {
            self.reset();
        }
        drift
    }

    /// Forget the current regime (called internally after each detection).
    pub fn reset(&mut self) {
        self.n = 0;
        self.mean = 0.0;
        self.m_down = 0.0;
        self.m_up = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stable_signal_never_fires() {
        let mut ph = PageHinkley::new(0.005, 0.05, 4);
        for i in 0..200 {
            // Tiny deterministic ripple around 0.7, amplitude < delta.
            let x = 0.7 + 0.002 * ((i % 3) as f64 - 1.0);
            assert_eq!(ph.update(x), None, "sample {i}");
        }
        assert!((ph.mean() - 0.7).abs() < 0.01);
    }

    #[test]
    fn step_down_fires_down_then_resets() {
        let mut ph = PageHinkley::new(0.005, 0.05, 4);
        for _ in 0..30 {
            assert_eq!(ph.update(0.8), None);
        }
        let mut fired = None;
        for i in 0..30 {
            if let Some(d) = ph.update(0.6) {
                fired = Some((i, d));
                break;
            }
        }
        let (i, d) = fired.expect("step change must be detected");
        assert_eq!(d, Drift::Down);
        assert!(i < 10, "detection latency {i}");
        assert_eq!(ph.samples(), 0, "detector must reset after firing");
    }

    #[test]
    fn step_up_fires_up() {
        let mut ph = PageHinkley::new(0.005, 0.05, 4);
        for _ in 0..30 {
            ph.update(0.4);
        }
        let fired = (0..30).find_map(|_| ph.update(0.65));
        assert_eq!(fired, Some(Drift::Up));
    }

    #[test]
    fn deterministic_for_identical_streams() {
        let series: Vec<f64> =
            (0..300).map(|i| if (i / 60) % 2 == 0 { 0.75 } else { 0.62 }).collect();
        let run = |series: &[f64]| -> Vec<(usize, Drift)> {
            let mut ph = PageHinkley::new(0.005, 0.05, 4);
            series
                .iter()
                .enumerate()
                .filter_map(|(i, &x)| ph.update(x).map(|d| (i, d)))
                .collect()
        };
        let a = run(&series);
        let b = run(&series);
        assert_eq!(a, b);
        assert!(!a.is_empty(), "alternating phases must produce detections");
    }
}
