//! `acpc` binary — CLI front-end for the library. See `acpc help`.

use anyhow::Result;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = acpc::cli::run(argv)?;
    std::process::exit(code);
}
