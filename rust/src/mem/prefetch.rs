//! Hardware prefetcher models — the pollution *source* the paper controls.
//!
//! LLM inference streams defeat simple prefetchers: weight-tile scans are
//! regular (stride succeeds), but embedding lookups and cross-session KV
//! reads are effectively random, so next-line/stride prefetches there insert
//! dead lines — exactly the pollution ACPC is built to suppress.

use crate::util::rng::Xoshiro256;
use crate::util::hash::FastMap;

/// A prefetcher observes demand accesses at a cache level and proposes
/// candidate lines to fill.
pub trait Prefetcher: Send {
    fn name(&self) -> &'static str;

    /// `hit`: whether the observed demand access hit. Candidates are
    /// returned into `out` (cleared by the caller).
    fn observe(&mut self, pc: u64, line: u64, hit: bool, out: &mut Vec<u64>);

    fn issued(&self) -> u64;
}

/// No prefetching (ablation baseline).
pub struct NoPrefetch;

impl Prefetcher for NoPrefetch {
    fn name(&self) -> &'static str {
        "none"
    }

    fn observe(&mut self, _pc: u64, _line: u64, _hit: bool, _out: &mut Vec<u64>) {}

    fn issued(&self) -> u64 {
        0
    }
}

/// Next-N-line prefetcher: on a miss, fetch the following `degree` lines.
pub struct NextLine {
    degree: usize,
    issued: u64,
}

impl NextLine {
    pub fn new(degree: usize) -> Self {
        Self { degree, issued: 0 }
    }
}

impl Prefetcher for NextLine {
    fn name(&self) -> &'static str {
        "nextline"
    }

    fn observe(&mut self, _pc: u64, line: u64, hit: bool, out: &mut Vec<u64>) {
        if !hit {
            for d in 1..=self.degree as u64 {
                out.push(line + d);
                self.issued += 1;
            }
        }
    }

    fn issued(&self) -> u64 {
        self.issued
    }
}

/// PC-indexed stride prefetcher (classic RPT): learns a per-PC line stride,
/// issues `degree` strided candidates once the stride is confirmed twice.
pub struct Stride {
    degree: usize,
    table: FastMap<u64, StrideEntry>,
    capacity: usize,
    issued: u64,
}

#[derive(Debug, Clone, Copy, Default)]
struct StrideEntry {
    last_line: u64,
    stride: i64,
    confidence: u8,
}

impl Stride {
    pub fn new(degree: usize) -> Self {
        Self { degree, table: FastMap::default(), capacity: 4096, issued: 0 }
    }
}

impl Prefetcher for Stride {
    fn name(&self) -> &'static str {
        "stride"
    }

    fn observe(&mut self, pc: u64, line: u64, _hit: bool, out: &mut Vec<u64>) {
        if self.table.len() >= self.capacity && !self.table.contains_key(&pc) {
            self.table.clear(); // cheap bulk aging
        }
        let e = self.table.entry(pc).or_default();
        if e.last_line != 0 {
            let s = line as i64 - e.last_line as i64;
            if s == e.stride && s != 0 {
                e.confidence = (e.confidence + 1).min(3);
            } else {
                e.stride = s;
                e.confidence = 0;
            }
        }
        e.last_line = line;
        if e.confidence >= 2 && e.stride != 0 {
            let stride = e.stride;
            for d in 1..=self.degree as i64 {
                let cand = line as i64 + stride * d;
                if cand > 0 {
                    out.push(cand as u64);
                    self.issued += 1;
                }
            }
        }
    }

    fn issued(&self) -> u64 {
        self.issued
    }
}

/// Markov / correlation prefetcher: remembers "line B followed line A" pairs
/// observed on misses and prefetches the recorded successor. Single-successor
/// table with bulk aging (deterministic — HashMap iteration order would leak
/// process-level nondeterminism into the simulation) — deliberately
/// mispredicts on LLM streams whose successors are context-dependent (a
/// pollution generator).
pub struct Correlation {
    table: FastMap<u64, u64>,
    capacity: usize,
    last_miss: u64,
    issued: u64,
    _rng: Xoshiro256,
}

impl Correlation {
    pub fn new(capacity: usize, seed: u64) -> Self {
        Self { table: FastMap::default(), capacity, last_miss: 0, issued: 0, _rng: Xoshiro256::new(seed) }
    }
}

impl Prefetcher for Correlation {
    fn name(&self) -> &'static str {
        "correlation"
    }

    fn observe(&mut self, _pc: u64, line: u64, hit: bool, out: &mut Vec<u64>) {
        if hit {
            return;
        }
        if self.last_miss != 0 {
            if self.table.len() >= self.capacity && !self.table.contains_key(&self.last_miss) {
                self.table.clear(); // deterministic bulk aging
            }
            self.table.insert(self.last_miss, line);
        }
        if let Some(&succ) = self.table.get(&line) {
            out.push(succ);
            self.issued += 1;
        }
        self.last_miss = line;
    }

    fn issued(&self) -> u64 {
        self.issued
    }
}

/// Composite: union of sub-prefetcher candidates (deduplicated) — the
/// "aggressive multi-engine" configuration used for Table 1, which creates
/// realistic pollution pressure.
pub struct Composite {
    subs: Vec<Box<dyn Prefetcher>>,
    scratch: Vec<u64>,
}

impl Composite {
    pub fn new(subs: Vec<Box<dyn Prefetcher>>) -> Self {
        Self { subs, scratch: Vec::with_capacity(8) }
    }
}

impl Prefetcher for Composite {
    fn name(&self) -> &'static str {
        "composite"
    }

    fn observe(&mut self, pc: u64, line: u64, hit: bool, out: &mut Vec<u64>) {
        self.scratch.clear();
        for s in &mut self.subs {
            s.observe(pc, line, hit, &mut self.scratch);
        }
        for &c in &self.scratch {
            if !out.contains(&c) {
                out.push(c);
            }
        }
    }

    fn issued(&self) -> u64 {
        self.subs.iter().map(|s| s.issued()).sum()
    }
}

/// Factory: `none | nextline | stride | correlation | composite`.
pub fn make_prefetcher(name: &str, seed: u64) -> Option<Box<dyn Prefetcher>> {
    let p: Box<dyn Prefetcher> = match name {
        "none" => Box::new(NoPrefetch),
        "nextline" => Box::new(NextLine::new(2)),
        "stride" => Box::new(Stride::new(2)),
        "correlation" => Box::new(Correlation::new(8192, seed)),
        "composite" => Box::new(Composite::new(vec![
            Box::new(NextLine::new(1)),
            Box::new(Stride::new(2)),
            Box::new(Correlation::new(4096, seed ^ 0xC0)),
        ])),
        _ => return None,
    };
    Some(p)
}

pub const PREFETCHER_NAMES: &[&str] = &["none", "nextline", "stride", "correlation", "composite"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nextline_on_miss_only() {
        let mut p = NextLine::new(2);
        let mut out = Vec::new();
        p.observe(0, 100, true, &mut out);
        assert!(out.is_empty());
        p.observe(0, 100, false, &mut out);
        assert_eq!(out, vec![101, 102]);
        assert_eq!(p.issued(), 2);
    }

    #[test]
    fn stride_learns_and_fires() {
        let mut p = Stride::new(2);
        let mut out = Vec::new();
        for i in 0..5u64 {
            out.clear();
            p.observe(0x7, 1000 + i * 4, false, &mut out);
        }
        // stride 4 confirmed → predictions 4 and 8 ahead.
        assert_eq!(out, vec![1016 + 4, 1016 + 8]);
    }

    #[test]
    fn stride_resets_on_irregular() {
        let mut p = Stride::new(1);
        let mut out = Vec::new();
        let mut seq = vec![10u64, 14, 18, 22]; // stride 4 learns
        seq.extend([1000, 3, 777, 12]); // chaos
        for l in seq {
            out.clear();
            p.observe(0x9, l, false, &mut out);
        }
        assert!(out.is_empty(), "no prediction after irregular stream: {out:?}");
    }

    #[test]
    fn correlation_remembers_successor() {
        let mut p = Correlation::new(64, 5);
        let mut out = Vec::new();
        p.observe(0, 7, false, &mut out); // last_miss = 7
        p.observe(0, 9, false, &mut out); // table[7] = 9
        out.clear();
        p.observe(0, 7, false, &mut out); // sees 7 again → predicts 9
        assert_eq!(out, vec![9]);
    }

    #[test]
    fn composite_dedups() {
        let mut p = Composite::new(vec![Box::new(NextLine::new(1)), Box::new(NextLine::new(2))]);
        let mut out = Vec::new();
        p.observe(0, 50, false, &mut out);
        assert_eq!(out, vec![51, 52]);
    }

    #[test]
    fn factory_names() {
        for n in PREFETCHER_NAMES {
            assert!(make_prefetcher(n, 1).is_some(), "{n}");
        }
        assert!(make_prefetcher("bogus", 1).is_none());
    }
}
