//! Multi-level set-associative cache simulator — the substrate standing in
//! for the paper's Gem5 + PyTorch cache emulator (DESIGN.md §3). Models the
//! structures the paper's metrics need: per-level hit/miss accounting,
//! prefetch-fill tracking (pollution), write-back traffic, and a latency
//! model for AMAT / miss-penalty / throughput derivation.

pub mod cache;
pub mod hierarchy;
pub mod prefetch;

pub use cache::{Cache, CacheConfig, CacheStats, EvictedLine};
pub use hierarchy::{Hierarchy, HierarchyConfig, LevelConfig, ServiceLevel};
