//! Single-level set-associative cache with a pluggable replacement policy
//! and prefetch-pollution accounting.

use crate::policy::{AccessMeta, Policy};

/// Static geometry of one cache level.
#[derive(Debug, Clone)]
pub struct CacheConfig {
    pub name: String,
    pub size_bytes: u64,
    pub assoc: usize,
    pub line_bytes: u64,
}

impl CacheConfig {
    pub fn new(name: &str, size_bytes: u64, assoc: usize) -> Self {
        Self { name: name.into(), size_bytes, assoc, line_bytes: 64 }
    }

    /// Number of sets, if the geometry is valid. The error message names
    /// the cache and the offending dimension so the CLI can surface it.
    pub fn checked_num_sets(&self) -> Result<usize, String> {
        if self.assoc == 0 {
            return Err(format!("{}: associativity must be > 0", self.name));
        }
        if self.line_bytes == 0 || !self.line_bytes.is_power_of_two() {
            return Err(format!(
                "{}: line size must be a power of two, got {} B",
                self.name, self.line_bytes
            ));
        }
        let way_bytes = self.line_bytes * self.assoc as u64;
        if self.size_bytes == 0 || self.size_bytes % way_bytes != 0 {
            return Err(format!(
                "{}: size {} B is not a multiple of line×assoc ({} B)",
                self.name, self.size_bytes, way_bytes
            ));
        }
        let sets = self.size_bytes / way_bytes;
        if !sets.is_power_of_two() {
            return Err(format!(
                "{}: {} sets ({} B / {} B lines / {}-way) is not a power of two — \
                 pick a size that yields 2^k sets",
                self.name, sets, self.size_bytes, self.line_bytes, self.assoc
            ));
        }
        Ok(sets as usize)
    }

    /// Config-time validation; run before constructing a [`Cache`].
    pub fn validate(&self) -> Result<(), String> {
        self.checked_num_sets().map(|_| ())
    }

    /// Number of sets. Geometry is validated at the config boundary
    /// (`HierarchyConfig::validate` / CLI / JSON overrides); reaching this
    /// with an invalid config is a programmer error.
    pub fn num_sets(&self) -> usize {
        self.checked_num_sets().expect("cache geometry should be validated at config time")
    }
}

/// State of one resident line.
#[derive(Debug, Clone, Copy, Default)]
pub struct LineState {
    pub line: u64,
    pub valid: bool,
    pub dirty: bool,
    /// Filled by a prefetch and not yet demand-referenced.
    pub was_prefetch: bool,
    /// Demand-referenced at least once since fill.
    pub referenced: bool,
}

/// What fell out of the cache on a fill.
#[derive(Debug, Clone, Copy)]
pub struct EvictedLine {
    pub line: u64,
    pub dirty: bool,
    pub was_prefetch_dead: bool,
    pub referenced: bool,
}

/// Counters for the paper's cache-level metrics.
#[derive(Debug, Clone, Default)]
pub struct CacheStats {
    pub demand_accesses: u64,
    pub demand_hits: u64,
    pub demand_misses: u64,
    pub writes: u64,
    /// Fills triggered by the prefetcher.
    pub prefetch_fills: u64,
    /// First demand hit on a prefetched line (useful prefetch).
    pub prefetch_useful: u64,
    /// Prefetched lines evicted without ever being demand-referenced.
    pub dead_prefetch_evictions: u64,
    /// Referenced demand lines evicted to make room for a *prefetch* fill —
    /// the direct pollution event (useful data displaced by a prefetch).
    pub demand_evicted_by_prefetch: u64,
    pub evictions: u64,
    pub writebacks: u64,
    pub invalidations: u64,
}

impl CacheStats {
    /// Exact merge for set-sharded simulation: every field is a monotone
    /// event counter over a disjoint set partition, so the aggregate run's
    /// stats are the field-wise sum of the shard stats.
    pub fn merge(&mut self, other: &CacheStats) {
        self.demand_accesses += other.demand_accesses;
        self.demand_hits += other.demand_hits;
        self.demand_misses += other.demand_misses;
        self.writes += other.writes;
        self.prefetch_fills += other.prefetch_fills;
        self.prefetch_useful += other.prefetch_useful;
        self.dead_prefetch_evictions += other.dead_prefetch_evictions;
        self.demand_evicted_by_prefetch += other.demand_evicted_by_prefetch;
        self.evictions += other.evictions;
        self.writebacks += other.writebacks;
        self.invalidations += other.invalidations;
    }

    pub fn hit_rate(&self) -> f64 {
        if self.demand_accesses == 0 {
            return f64::NAN;
        }
        self.demand_hits as f64 / self.demand_accesses as f64
    }

    /// Prefetch pollution ratio: share of all fills that were prefetches
    /// evicted dead (wasted capacity + displaced victims). The paper's PPR.
    pub fn pollution_ratio(&self) -> f64 {
        let fills = self.demand_misses + self.prefetch_fills;
        if fills == 0 {
            return 0.0;
        }
        self.dead_prefetch_evictions as f64 / fills as f64
    }

    /// Prefetch accuracy: useful / issued-fills.
    pub fn prefetch_accuracy(&self) -> f64 {
        if self.prefetch_fills == 0 {
            return f64::NAN;
        }
        self.prefetch_useful as f64 / self.prefetch_fills as f64
    }
}

/// Outcome of a demand access.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lookup {
    Hit,
    Miss,
}

pub struct Cache {
    cfg: CacheConfig,
    num_sets: usize,
    set_mask: u64,
    /// Low line bits consumed by the shard router before set selection:
    /// `set_of(line) = (line >> set_shift) & set_mask`. 0 for an unsharded
    /// cache. A shard's sub-cache owns every `shards`-th set of the full
    /// geometry, and this shift makes its local set indexing agree with the
    /// global run set-for-set (see `sim::shard`).
    set_shift: u32,
    lines: Vec<LineState>,
    policy: Box<dyn Policy>,
    pub stats: CacheStats,
    /// EWMA of dead-prefetch occupancy per set, sampled lazily; feeds the
    /// policy's `occupancy_hint` (PARM pressure signal).
    occupancy_sample_period: u64,
    accesses_since_sample: u64,
    /// Incremental residency counters so `occupancy`/`useful_fraction` are
    /// O(1) instead of O(lines) scans (they sit on the per-access EMU and
    /// telemetry paths).
    valid_count: usize,
    referenced_count: usize,
    /// Per-set count of resident never-referenced prefetch lines, kept
    /// incrementally so `maybe_sample_occupancy` reads a counter instead of
    /// scanning the set. Invariant: `was_prefetch ⇒ !referenced` (the first
    /// demand hit clears `was_prefetch` as it sets `referenced`).
    dead_prefetch_per_set: Vec<u16>,
}

impl Cache {
    pub fn new(cfg: CacheConfig, policy: Box<dyn Policy>) -> Self {
        Self::with_set_shift(cfg, policy, 0)
    }

    /// Shard-aware constructor: `cfg` describes this shard's slice of the
    /// sets and `set_shift` the number of low line bits the shard router
    /// consumed (`log2(shards)`).
    pub fn with_set_shift(cfg: CacheConfig, policy: Box<dyn Policy>, set_shift: u32) -> Self {
        let num_sets = cfg.num_sets();
        Self {
            num_sets,
            set_mask: num_sets as u64 - 1,
            set_shift,
            lines: vec![LineState::default(); num_sets * cfg.assoc],
            policy,
            stats: CacheStats::default(),
            occupancy_sample_period: 64,
            accesses_since_sample: 0,
            valid_count: 0,
            referenced_count: 0,
            dead_prefetch_per_set: vec![0; num_sets],
            cfg,
        }
    }

    pub fn config(&self) -> &CacheConfig {
        &self.cfg
    }

    pub fn num_sets(&self) -> usize {
        self.num_sets
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    #[inline]
    pub fn set_of(&self, line: u64) -> usize {
        ((line >> self.set_shift) & self.set_mask) as usize
    }

    #[inline]
    fn idx(&self, set: usize, way: usize) -> usize {
        set * self.cfg.assoc + way
    }

    /// Non-mutating presence probe.
    pub fn probe(&self, line: u64) -> Option<usize> {
        let set = self.set_of(line);
        (0..self.cfg.assoc).find(|&w| {
            let l = &self.lines[self.idx(set, w)];
            l.valid && l.line == line
        })
    }

    /// Demand access (read or write). Returns hit/miss; the caller fills on
    /// miss after servicing the lower level.
    pub fn access(&mut self, line: u64, meta: &AccessMeta, is_write: bool) -> Lookup {
        self.stats.demand_accesses += 1;
        if is_write {
            self.stats.writes += 1;
        }
        self.maybe_sample_occupancy(line);
        let set = self.set_of(line);
        if let Some(way) = self.probe(line) {
            self.stats.demand_hits += 1;
            let l = &mut self.lines[set * self.cfg.assoc + way];
            if l.was_prefetch {
                l.was_prefetch = false;
                self.stats.prefetch_useful += 1;
                self.dead_prefetch_per_set[set] -= 1;
            }
            if !l.referenced {
                self.referenced_count += 1;
            }
            l.referenced = true;
            if is_write {
                l.dirty = true;
            }
            self.policy.on_hit(set, way, meta);
            Lookup::Hit
        } else {
            self.stats.demand_misses += 1;
            Lookup::Miss
        }
    }

    /// Install `line`. `meta.is_prefetch` distinguishes prefetch fills.
    /// Returns the eviction, if the set was full.
    pub fn fill(&mut self, line: u64, meta: &AccessMeta, is_write: bool) -> Option<EvictedLine> {
        debug_assert!(self.probe(line).is_none(), "double fill of {line:#x}");
        let set = self.set_of(line);
        let assoc = self.cfg.assoc;
        // Free way if any.
        let free = (0..assoc).find(|&w| !self.lines[set * assoc + w].valid);
        let (way, evicted) = match free {
            Some(w) => {
                self.valid_count += 1;
                (w, None)
            }
            None => {
                let w = self.policy.victim(set);
                debug_assert!(w < assoc);
                let old = self.lines[set * assoc + w];
                self.stats.evictions += 1;
                if old.dirty {
                    self.stats.writebacks += 1;
                }
                let dead_prefetch = old.was_prefetch && !old.referenced;
                if dead_prefetch {
                    self.stats.dead_prefetch_evictions += 1;
                    self.dead_prefetch_per_set[set] -= 1;
                }
                if old.referenced {
                    self.referenced_count -= 1;
                }
                if meta.is_prefetch && old.referenced {
                    self.stats.demand_evicted_by_prefetch += 1;
                }
                (
                    w,
                    Some(EvictedLine {
                        line: old.line,
                        dirty: old.dirty,
                        was_prefetch_dead: dead_prefetch,
                        referenced: old.referenced,
                    }),
                )
            }
        };
        if meta.is_prefetch {
            self.stats.prefetch_fills += 1;
            self.dead_prefetch_per_set[set] += 1;
        } else {
            self.referenced_count += 1;
        }
        self.lines[set * assoc + way] = LineState {
            line,
            valid: true,
            dirty: is_write,
            was_prefetch: meta.is_prefetch,
            referenced: !meta.is_prefetch,
        };
        self.policy.on_fill(set, way, meta);
        evicted
    }

    /// Drop a line if present (KV slot recycling, coherence-ish upcalls).
    pub fn invalidate(&mut self, line: u64) -> bool {
        if let Some(way) = self.probe(line) {
            let set = self.set_of(line);
            let idx = set * self.cfg.assoc + way;
            let old = self.lines[idx];
            self.lines[idx].valid = false;
            self.valid_count -= 1;
            if old.referenced {
                self.referenced_count -= 1;
            }
            if old.was_prefetch {
                self.dead_prefetch_per_set[set] -= 1;
            }
            self.stats.invalidations += 1;
            self.policy.on_invalidate(set, way);
            true
        } else {
            false
        }
    }

    /// Refresh the predictor's utility score for a resident line.
    pub fn update_utility_line(&mut self, line: u64, utility: f32) -> bool {
        if let Some(way) = self.probe(line) {
            let set = self.set_of(line);
            self.policy.update_utility(set, way, utility);
            true
        } else {
            false
        }
    }

    /// Forget every stored predicted utility in the policy (adaptive
    /// throttle / predictor hot swap). No-op for classic policies.
    pub fn reset_utilities(&mut self) {
        self.policy.reset_utilities();
    }

    /// Valid-line occupancy in [0,1]. O(1): maintained incrementally.
    pub fn occupancy(&self) -> f64 {
        self.valid_count as f64 / self.lines.len() as f64
    }

    /// Effective memory utilization: referenced fraction of resident lines
    /// (the paper's EMU numerator — useful lines / occupied capacity).
    /// O(1): maintained incrementally.
    pub fn useful_fraction(&self) -> f64 {
        if self.valid_count == 0 {
            return f64::NAN;
        }
        self.referenced_count as f64 / self.valid_count as f64
    }

    fn maybe_sample_occupancy(&mut self, line: u64) {
        self.accesses_since_sample += 1;
        if self.accesses_since_sample < self.occupancy_sample_period {
            return;
        }
        self.accesses_since_sample = 0;
        let set = self.set_of(line);
        // Incremental per-set dead-prefetch counter instead of an O(assoc)
        // way scan (this sits on the demand-access hot path).
        let dead = self.dead_prefetch_per_set[set] as f64;
        self.policy.occupancy_hint(set, dead / self.cfg.assoc as f64);
    }

    /// Iterate resident lines (diagnostics / EMU sampling).
    pub fn resident_lines(&self) -> impl Iterator<Item = &LineState> {
        self.lines.iter().filter(|l| l.valid)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::make_policy;
    use crate::trace::StreamKind;

    fn mk(size_kb: u64, assoc: usize, policy: &str) -> Cache {
        let cfg = CacheConfig::new("t", size_kb * 1024, assoc);
        let p = make_policy(policy, cfg.num_sets(), assoc, 1).unwrap();
        Cache::new(cfg, p)
    }

    fn demand(line: u64) -> AccessMeta {
        AccessMeta::demand(line, 0x10, StreamKind::Weight)
    }

    fn prefetch(line: u64) -> AccessMeta {
        AccessMeta::prefetch(line, 0x10, StreamKind::Weight)
    }

    #[test]
    fn geometry_validation() {
        assert!(CacheConfig::new("ok", 4 * 1024, 4).validate().is_ok());
        // 96 KiB / 8-way / 64 B → 192 sets: not a power of two.
        let e = CacheConfig::new("bad", 96 * 1024, 8).validate().unwrap_err();
        assert!(e.contains("bad") && e.contains("power of two"), "{e}");
        assert!(CacheConfig::new("z", 0, 4).validate().is_err());
        assert!(CacheConfig::new("a0", 4 * 1024, 0).validate().is_err());
        // Size not a multiple of line×assoc.
        assert!(CacheConfig::new("odd", 4 * 1024 + 64, 4).validate().is_err());
    }

    #[test]
    fn hit_after_fill() {
        let mut c = mk(4, 4, "lru");
        let line = 0x100;
        assert_eq!(c.access(line, &demand(line), false), Lookup::Miss);
        c.fill(line, &demand(line), false);
        assert_eq!(c.access(line, &demand(line), false), Lookup::Hit);
        assert_eq!(c.stats.demand_hits, 1);
        assert_eq!(c.stats.demand_misses, 1);
    }

    #[test]
    fn capacity_and_eviction() {
        // 4 KiB, 4-way, 64B lines → 16 sets. Fill 5 lines mapping to set 0.
        let mut c = mk(4, 4, "lru");
        let lines: Vec<u64> = (0..5).map(|i| i * 16).collect(); // same set
        for &l in &lines {
            assert_eq!(c.set_of(l), 0);
            c.access(l, &demand(l), false);
            c.fill(l, &demand(l), false);
        }
        assert_eq!(c.stats.evictions, 1);
        // LRU: first line evicted.
        assert!(c.probe(lines[0]).is_none());
        assert!(c.probe(lines[4]).is_some());
    }

    #[test]
    fn writeback_on_dirty_eviction() {
        let mut c = mk(4, 4, "lru");
        for i in 0..5u64 {
            let l = i * 16;
            c.access(l, &demand(l), true);
            c.fill(l, &demand(l), true);
        }
        assert_eq!(c.stats.writebacks, 1);
    }

    #[test]
    fn pollution_accounting() {
        let mut c = mk(4, 4, "lru");
        // 4 demand lines referenced, then 4 dead prefetches displace them.
        for i in 0..4u64 {
            let l = i * 16;
            c.access(l, &demand(l), false);
            c.fill(l, &demand(l), false);
        }
        for i in 4..8u64 {
            let l = i * 16;
            c.fill(l, &prefetch(l), false);
        }
        assert_eq!(c.stats.prefetch_fills, 4);
        assert_eq!(c.stats.demand_evicted_by_prefetch, 4);
        // Evict the prefetches (never referenced) with more demand fills.
        for i in 8..12u64 {
            let l = i * 16;
            c.access(l, &demand(l), false);
            c.fill(l, &demand(l), false);
        }
        assert_eq!(c.stats.dead_prefetch_evictions, 4);
        assert!(c.stats.pollution_ratio() > 0.0);
    }

    #[test]
    fn useful_prefetch_counted_once() {
        let mut c = mk(4, 4, "lru");
        let l = 0x40;
        c.fill(l, &prefetch(l), false);
        assert_eq!(c.access(l, &demand(l), false), Lookup::Hit);
        assert_eq!(c.access(l, &demand(l), false), Lookup::Hit);
        assert_eq!(c.stats.prefetch_useful, 1);
        assert_eq!(c.stats.dead_prefetch_evictions, 0);
    }

    #[test]
    fn invalidate_then_miss() {
        let mut c = mk(4, 4, "lru");
        let l = 0x80;
        c.access(l, &demand(l), false);
        c.fill(l, &demand(l), false);
        assert!(c.invalidate(l));
        assert!(!c.invalidate(l));
        assert_eq!(c.access(l, &demand(l), false), Lookup::Miss);
    }

    #[test]
    fn utility_update_only_for_resident() {
        let mut c = mk(4, 4, "acpc");
        let l = 0x200;
        assert!(!c.update_utility_line(l, 0.9));
        c.fill(l, &demand(l), false);
        assert!(c.update_utility_line(l, 0.9));
    }

    #[test]
    fn occupancy_and_useful_fraction() {
        let mut c = mk(4, 4, "lru");
        assert_eq!(c.occupancy(), 0.0);
        c.fill(0, &demand(0), false);
        c.fill(16, &prefetch(16), false);
        assert!((c.occupancy() - 2.0 / 64.0).abs() < 1e-9);
        assert!((c.useful_fraction() - 0.5).abs() < 1e-9);
    }

    /// The incremental residency counters must agree with a full line scan
    /// after an arbitrary access/fill/invalidate history.
    #[test]
    fn incremental_counters_match_full_scan() {
        use crate::util::rng::Xoshiro256;
        let mut c = mk(4, 4, "lru");
        let mut rng = Xoshiro256::new(0xC0FFEE);
        for i in 0..20_000u64 {
            let line = rng.next_u64() % 128;
            match i % 5 {
                0 | 1 => {
                    if c.access(line, &demand(line), false) == Lookup::Miss {
                        c.fill(line, &demand(line), false);
                    }
                }
                2 => {
                    if c.probe(line).is_none() {
                        c.fill(line, &prefetch(line), false);
                    }
                }
                3 => {
                    let _ = c.access(line, &demand(line), true);
                    if c.probe(line).is_none() {
                        c.fill(line, &demand(line), true);
                    }
                }
                _ => {
                    c.invalidate(line);
                }
            }
        }
        let valid = c.lines.iter().filter(|l| l.valid).count();
        let referenced = c.lines.iter().filter(|l| l.valid && l.referenced).count();
        assert_eq!(c.valid_count, valid);
        assert_eq!(c.referenced_count, referenced);
        assert!((c.occupancy() - valid as f64 / c.lines.len() as f64).abs() < 1e-12);
        for set in 0..c.num_sets() {
            let dead = (0..c.cfg.assoc)
                .filter(|&w| {
                    let l = &c.lines[set * c.cfg.assoc + w];
                    l.valid && l.was_prefetch && !l.referenced
                })
                .count();
            assert_eq!(c.dead_prefetch_per_set[set] as usize, dead, "set {set}");
        }
    }

    /// A set-shifted cache must index sets by the post-shard line bits.
    #[test]
    fn set_shift_indexes_high_bits() {
        let cfg = CacheConfig::new("t", 4 * 1024, 4); // 16 sets
        let p = make_policy("lru", cfg.num_sets(), 4, 1).unwrap();
        let c = Cache::with_set_shift(cfg, p, 2); // 4-way sharding
        // Lines congruent mod 4 (same shard) spread over sets by bits 2..6.
        assert_eq!(c.set_of(0b0000_01), 0);
        assert_eq!(c.set_of(0b0001_01), 1);
        assert_eq!(c.set_of(0b1111_01), 15);
        // Next multiple wraps around the 16-set mask.
        assert_eq!(c.set_of((1 << 6) | 1), 0);
    }

    #[test]
    fn stats_merge_sums_counters() {
        let mut a =
            CacheStats { demand_accesses: 3, demand_hits: 2, evictions: 1, ..Default::default() };
        let b =
            CacheStats { demand_accesses: 7, demand_hits: 1, writebacks: 4, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.demand_accesses, 10);
        assert_eq!(a.demand_hits, 3);
        assert_eq!(a.evictions, 1);
        assert_eq!(a.writebacks, 4);
    }
}
