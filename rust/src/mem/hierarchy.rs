//! Three-level cache hierarchy with an L2-attached prefetcher and a latency
//! model — the simulated memory system for all experiments. The replacement
//! policy *under test* governs L2 (the level whose miss penalty Table 1
//! reports); L1 uses LRU (small, latency-filtered) and L3 uses DRRIP (a
//! realistic LLC default that is not the subject of the study).

use super::cache::{Cache, CacheConfig, Lookup};
use super::prefetch::{make_prefetcher, Prefetcher};
use crate::policy::{make_policy, AccessMeta, Policy};
use crate::trace::Access;
use crate::util::hash::FastMap;

/// Geometry + hit latency (cycles) of one level.
#[derive(Debug, Clone)]
pub struct LevelConfig {
    pub size_bytes: u64,
    pub assoc: usize,
    pub hit_latency: u64,
}

/// Full hierarchy configuration.
#[derive(Debug, Clone)]
pub struct HierarchyConfig {
    pub l1: LevelConfig,
    pub l2: LevelConfig,
    pub l3: LevelConfig,
    pub dram_latency: u64,
    /// Prefetcher attached to L2 (`none|nextline|stride|correlation|composite`).
    pub prefetcher: String,
    /// LLC replacement policy. DRRIP (the realistic default) carries global
    /// state (PSEL, BRRIP RNG), so sharded runs instantiate it per shard;
    /// pick a set-local policy (`srrip`, `lru`) when exact shard-count
    /// invariance of AMAT/miss-penalty is required.
    pub l3_policy: String,
    pub seed: u64,
}

impl HierarchyConfig {
    /// Scaled-down hierarchy for fast simulation: working sets in the trace
    /// generator are sized against these (DESIGN.md §3). Latencies follow
    /// EPYC-7763 ratios.
    pub fn scaled() -> Self {
        Self {
            l1: LevelConfig { size_bytes: 16 * 1024, assoc: 8, hit_latency: 4 },
            l2: LevelConfig { size_bytes: 512 * 1024, assoc: 8, hit_latency: 14 },
            l3: LevelConfig { size_bytes: 8 * 1024 * 1024, assoc: 16, hit_latency: 46 },
            dram_latency: 220,
            prefetcher: "composite".into(),
            l3_policy: "drrip".into(),
            seed: 0xCAFE,
        }
    }

    /// Paper-faithful EPYC 7763 single-core slice (L1 64 KB, L2 512 KB,
    /// L3 64 MB shared → 4 MB per-core slice here). Slower to simulate.
    pub fn epyc7763() -> Self {
        Self {
            l1: LevelConfig { size_bytes: 64 * 1024, assoc: 8, hit_latency: 4 },
            l2: LevelConfig { size_bytes: 512 * 1024, assoc: 8, hit_latency: 14 },
            l3: LevelConfig { size_bytes: 4 * 1024 * 1024, assoc: 16, hit_latency: 46 },
            dram_latency: 220,
            prefetcher: "composite".into(),
            l3_policy: "drrip".into(),
            seed: 0xCAFE,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "scaled" => Some(Self::scaled()),
            "epyc7763" | "epyc" => Some(Self::epyc7763()),
            _ => None,
        }
    }

    /// Config-time geometry validation for all three levels. Call at the
    /// CLI/JSON boundary so bad sizes surface as errors, not panics.
    pub fn validate(&self) -> Result<(), String> {
        for (name, lvl) in [("L1", &self.l1), ("L2", &self.l2), ("L3", &self.l3)] {
            CacheConfig::new(name, lvl.size_bytes, lvl.assoc).validate()?;
        }
        if make_policy(&self.l3_policy, 2, 2, 0).is_none() {
            return Err(format!("unknown L3 policy '{}'", self.l3_policy));
        }
        Ok(())
    }

    /// Can this hierarchy be split into `shards` set partitions? Requires a
    /// power-of-two shard count that divides *every* level's set count, so
    /// the low `log2(shards)` line bits select the same shard at L1, L2 and
    /// L3 and each shard owns an exact 1/shards slice of every level.
    pub fn validate_shards(&self, shards: usize) -> Result<(), String> {
        self.validate()?;
        if shards == 0 || !shards.is_power_of_two() {
            return Err(format!("shard count must be a power of two ≥ 1, got {shards}"));
        }
        for (name, lvl) in [("L1", &self.l1), ("L2", &self.l2), ("L3", &self.l3)] {
            let sets = CacheConfig::new(name, lvl.size_bytes, lvl.assoc).checked_num_sets()?;
            if shards > sets {
                return Err(format!(
                    "{name} has {sets} sets — cannot split into {shards} shards \
                     (shards must divide every level's set count)"
                ));
            }
        }
        Ok(())
    }
}

/// Which level serviced a demand access.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServiceLevel {
    L1,
    L2,
    L3,
    Dram,
}

pub struct Hierarchy {
    pub l1: Cache,
    pub l2: Cache,
    pub l3: Cache,
    cfg: HierarchyConfig,
    prefetcher: Box<dyn Prefetcher>,
    pf_buf: Vec<u64>,
    /// Latest predicted reuse utility per line (bounded). Fed by
    /// `update_utility`; consulted for demand metas with no explicit score
    /// and for prefetch filtering.
    utility: FastMap<u64, f32>,
    /// ACPC's prefetch filter (§3.1 "suppressing unnecessary prefetch
    /// pollution"): prefetch fills whose predicted utility is below the
    /// threshold are dropped outright. `None` disables filtering.
    pub prefetch_filter_threshold: Option<f32>,
    /// The threshold as configured at construction; `set_prefetch_throttled`
    /// restores it when the adaptive controller lifts a throttle.
    base_prefetch_filter_threshold: Option<f32>,
    /// Whether the adaptive controller currently holds prefetching in the
    /// conservative (raised-threshold) regime.
    prefetch_throttled: bool,
    pub prefetches_dropped: u64,
    /// Adaptive feedback (§3.4) on prefetch *sources*: per-PC (issued,
    /// useful) counts learned from observed outcomes; PCs with proven low
    /// accuracy get their candidates dropped. Only active when filtering is.
    pf_accuracy: FastMap<u64, (u32, u32)>,
    /// line → issuing PC for in-flight prefetches (outcome attribution).
    pf_inflight: FastMap<u64, u64>,
    /// Shard routing identity: this hierarchy only owns lines with
    /// `line & shard_mask == shard_index`. `mask = 0` for an unsharded run,
    /// so every line passes. Prefetch candidates outside the partition are
    /// dropped (a per-bank prefetcher cannot fill another bank) and counted
    /// in `cross_shard_prefetches_dropped`.
    shard_mask: u64,
    shard_index: u64,
    pub cross_shard_prefetches_dropped: u64,
    /// Total latency accumulated over all demand accesses.
    pub total_latency: u64,
    pub accesses: u64,
}

const UTILITY_CAP: usize = 1 << 17;

impl Hierarchy {
    /// `policy` governs L2. Panics on unknown names (caller validates).
    pub fn new(cfg: HierarchyConfig, policy: &str) -> Self {
        Self::new_sharded(cfg, policy, 0, 1)
    }

    /// One shard of a set-partitioned hierarchy: owns every `shards`-th set
    /// of each level (the sets whose lines satisfy
    /// `line & (shards-1) == shard`). With `shards == 1` this is exactly
    /// [`Hierarchy::new`]. Caller must have run
    /// [`HierarchyConfig::validate_shards`]; `policy` is per-shard (set-local
    /// policies behave identically to the unsharded run; policies with
    /// global state — DIP's PSEL, SHiP's SHCT — become per-shard, seeded by
    /// shard for determinism).
    pub fn new_sharded(cfg: HierarchyConfig, policy: &str, shard: usize, shards: usize) -> Self {
        assert!(shards.is_power_of_two() && shard < shards, "shard {shard}/{shards}");
        let set_shift = shards.trailing_zeros();
        // Well-separated per-shard seed stream (splitmix-style increment)
        // so stochastic tie-breaks differ across shards but are fixed for a
        // given (seed, shard) pair.
        let seed = cfg.seed ^ (shard as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mk = |name: &str, lvl: &LevelConfig, pol: &str, seed: u64| -> Cache {
            let ccfg = CacheConfig::new(name, lvl.size_bytes / shards as u64, lvl.assoc);
            let p: Box<dyn Policy> =
                make_policy(pol, ccfg.num_sets(), lvl.assoc, seed).unwrap_or_else(|| panic!("policy {pol}"));
            Cache::with_set_shift(ccfg, p, set_shift)
        };
        let l1 = mk("L1", &cfg.l1, "lru", seed ^ 1);
        let l2 = mk("L2", &cfg.l2, policy, seed ^ 2);
        let l3 = mk("L3", &cfg.l3, &cfg.l3_policy, seed ^ 3);
        let prefetcher = make_prefetcher(&cfg.prefetcher, seed ^ 4)
            .unwrap_or_else(|| panic!("prefetcher {}", cfg.prefetcher));
        // The prefetch filter is PARM's distinctive pollution-suppression
        // mechanism; enable it only for the ACPC policy.
        let prefetch_filter_threshold = if policy == "acpc" { Some(0.22) } else { None };
        Self {
            l1,
            l2,
            l3,
            cfg,
            prefetcher,
            pf_buf: Vec::with_capacity(8),
            utility: FastMap::default(),
            prefetch_filter_threshold,
            base_prefetch_filter_threshold: prefetch_filter_threshold,
            prefetch_throttled: false,
            prefetches_dropped: 0,
            pf_accuracy: FastMap::default(),
            pf_inflight: FastMap::default(),
            shard_mask: shards as u64 - 1,
            shard_index: shard as u64,
            cross_shard_prefetches_dropped: 0,
            total_latency: 0,
            accesses: 0,
        }
    }

    /// Has this PC's prefetch stream proven itself (in)accurate?
    fn pc_blacklisted(&self, pc: u64) -> bool {
        match self.pf_accuracy.get(&pc) {
            Some(&(issued, useful)) if issued >= 48 => (useful as f64) < 0.10 * issued as f64,
            _ => false,
        }
    }

    /// L2 fill with prefetch-outcome attribution: a dead-evicted prefetch
    /// settles its issuing PC's accuracy as a miss.
    fn l2_fill_tracked(&mut self, line: u64, meta: &AccessMeta, is_write: bool) {
        let evicted = self.l2.fill(line, meta, is_write);
        if self.prefetch_filter_threshold.is_some() {
            if let Some(ev) = evicted {
                if ev.was_prefetch_dead {
                    if let Some(pc) = self.pf_inflight.remove(&ev.line) {
                        self.record_pf_outcome(pc, false);
                    }
                }
            }
        }
    }

    fn record_pf_outcome(&mut self, pc: u64, useful: bool) {
        let e = self.pf_accuracy.entry(pc).or_insert((0, 0));
        e.0 += 1;
        if useful {
            e.1 += 1;
        }
        // Periodic halving keeps the estimate adaptive to phase changes.
        if e.0 >= 4096 {
            e.0 /= 2;
            e.1 /= 2;
        }
    }

    pub fn config(&self) -> &HierarchyConfig {
        &self.cfg
    }

    pub fn policy_name(&self) -> &'static str {
        self.l2.policy_name()
    }

    pub fn latency_of(&self, lvl: ServiceLevel) -> u64 {
        match lvl {
            ServiceLevel::L1 => self.cfg.l1.hit_latency,
            ServiceLevel::L2 => self.cfg.l1.hit_latency + self.cfg.l2.hit_latency,
            ServiceLevel::L3 => {
                self.cfg.l1.hit_latency + self.cfg.l2.hit_latency + self.cfg.l3.hit_latency
            }
            ServiceLevel::Dram => {
                self.cfg.l1.hit_latency
                    + self.cfg.l2.hit_latency
                    + self.cfg.l3.hit_latency
                    + self.cfg.dram_latency
            }
        }
    }

    /// Service one demand access end-to-end: probe L1→L2→L3→DRAM, fill the
    /// upper levels on the way back, run the L2 prefetcher, accumulate
    /// latency. Returns the servicing level.
    pub fn access(&mut self, acc: &Access, meta: &AccessMeta) -> ServiceLevel {
        let line = acc.line();
        let w = acc.is_write;
        self.accesses += 1;

        // Late-bind the latest completed prediction for this line.
        let mut meta = *meta;
        if meta.predicted_utility.is_none() && !self.utility.is_empty() {
            meta.predicted_utility = self.utility.get(&line).copied();
        }
        let meta = &meta;

        let lvl = if self.l1.access(line, meta, w) == Lookup::Hit {
            ServiceLevel::L1
        } else {
            // Prefetch-outcome attribution: first demand touch of an
            // in-flight prefetched line settles its issuing PC's score.
            if self.prefetch_filter_threshold.is_some() {
                if let Some(pc) = self.pf_inflight.remove(&line) {
                    let useful = self.l2.probe(line).is_some();
                    self.record_pf_outcome(pc, useful);
                }
            }
            let l2_res = self.l2.access(line, meta, w);
            // Prefetcher observes every L2 demand access.
            self.pf_buf.clear();
            self.prefetcher.observe(acc.pc, line, l2_res == Lookup::Hit, &mut self.pf_buf);

            let lvl = if l2_res == Lookup::Hit {
                self.l1.fill(line, meta, w);
                ServiceLevel::L2
            } else if self.l3.access(line, meta, w) == Lookup::Hit {
                self.l2_fill_tracked(line, meta, w);
                self.l1.fill(line, meta, w);
                ServiceLevel::L3
            } else {
                self.l3.fill(line, meta, w);
                self.l2_fill_tracked(line, meta, w);
                self.l1.fill(line, meta, w);
                ServiceLevel::Dram
            };

            // Issue prefetch fills into L2 (off the critical path; no
            // latency charged, but pollution is real).
            if !self.pf_buf.is_empty() {
                let buf = std::mem::take(&mut self.pf_buf);
                for &cand in &buf {
                    // Sharded runs: a candidate outside this shard's set
                    // partition belongs to another shard's hierarchy;
                    // filling it here would duplicate the line across
                    // partitions. (mask = 0 in unsharded runs ⇒ no-op.)
                    if cand & self.shard_mask != self.shard_index {
                        self.cross_shard_prefetches_dropped += 1;
                        continue;
                    }
                    if self.l2.probe(cand).is_some() {
                        continue;
                    }
                    let pred = self.utility.get(&cand).copied();
                    if let Some(th) = self.prefetch_filter_threshold {
                        // ACPC prefetch filter: (a) predicted-dead lines and
                        // (b) candidates from PCs with proven-bad accuracy
                        // are dropped before they pollute the cache.
                        if pred.map(|u| u < th).unwrap_or(false) || self.pc_blacklisted(acc.pc) {
                            self.prefetches_dropped += 1;
                            continue;
                        }
                        if self.pf_inflight.len() > (1 << 16) {
                            self.pf_inflight.clear();
                        }
                        self.pf_inflight.insert(cand, acc.pc);
                    }
                    let pf_meta = AccessMeta {
                        line: cand,
                        pc: acc.pc,
                        kind: meta.kind,
                        is_prefetch: true,
                        predicted_utility: pred,
                        next_use: None,
                    };
                    self.l2_fill_tracked(cand, &pf_meta, false);
                }
                self.pf_buf = buf;
            }
            lvl
        };
        self.total_latency += self.latency_of(lvl);
        lvl
    }

    /// Average memory access latency (cycles) so far.
    pub fn amat(&self) -> f64 {
        if self.accesses == 0 {
            return f64::NAN;
        }
        self.total_latency as f64 / self.accesses as f64
    }

    /// Record a completed prediction: cache it for future fills/filtering
    /// and refresh the resident L2 line if present (ACPC feedback path).
    pub fn update_utility(&mut self, line: u64, utility: f32) -> bool {
        if self.utility.len() >= UTILITY_CAP {
            self.utility.clear(); // cheap wholesale aging
        }
        self.utility.insert(line, utility);
        self.l2.update_utility_line(line, utility)
    }

    /// Latest known prediction for a line (diagnostics/tests).
    pub fn utility_of(&self, line: u64) -> Option<f32> {
        self.utility.get(&line).copied()
    }

    /// Drop every cached prediction *and* the per-line utilities already
    /// stamped into the L2 policy (adaptive throttle entry / predictor hot
    /// swap): subsequent fills see no utility, and resident lines stop
    /// being ranked by stale predictions.
    pub fn clear_utilities(&mut self) {
        self.utility.clear();
        self.l2.reset_utilities();
    }

    pub fn prefetches_issued(&self) -> u64 {
        self.prefetcher.issued()
    }

    /// Adaptive-controller hook (§3.4): while throttled, prefetching turns
    /// conservative — the filter threshold is raised so only high-confidence
    /// candidates get through — and the original threshold is restored when
    /// the throttle lifts. For policies that run unfiltered (no ACPC
    /// threshold) a throttle *installs* a filter at 0.5, so even they stop
    /// speculating on predicted-dead lines during unhealthy windows.
    pub fn set_prefetch_throttled(&mut self, on: bool) {
        if on == self.prefetch_throttled {
            return;
        }
        self.prefetch_throttled = on;
        self.prefetch_filter_threshold = if on {
            Some(match self.base_prefetch_filter_threshold {
                Some(base) => (base * 2.0).clamp(0.5, 0.95),
                None => 0.5,
            })
        } else {
            self.base_prefetch_filter_threshold
        };
    }

    /// Is the conservative (throttled) prefetch regime currently active?
    pub fn prefetch_throttled(&self) -> bool {
        self.prefetch_throttled
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Access, StreamKind};

    fn acc(addr: u64, pc: u64) -> Access {
        Access {
            time: 0,
            addr,
            pc,
            kind: StreamKind::Weight,
            session: 0,
            ctx_len: 0,
            layer: 0,
            is_write: false,
        }
    }

    fn meta_for(a: &Access) -> AccessMeta {
        AccessMeta::demand(a.line(), a.pc, a.kind)
    }

    fn small() -> HierarchyConfig {
        let mut c = HierarchyConfig::scaled();
        c.prefetcher = "none".into();
        c
    }

    #[test]
    fn miss_then_hits_climb_hierarchy() {
        let mut h = Hierarchy::new(small(), "lru");
        let a = acc(0x1000, 1);
        assert_eq!(h.access(&a, &meta_for(&a)), ServiceLevel::Dram);
        assert_eq!(h.access(&a, &meta_for(&a)), ServiceLevel::L1);
        assert_eq!(h.l1.stats.demand_hits, 1);
    }

    #[test]
    fn l1_evict_still_hits_l2() {
        let mut h = Hierarchy::new(small(), "lru");
        // L1 16KiB/8w → 32 sets. 9 lines in the same L1 set evict one,
        // but L2 (512 sets) keeps them all.
        let lines: Vec<u64> = (0..9).map(|i| (i * 32) << 6).collect();
        for &l in &lines {
            let a = acc(l, 2);
            h.access(&a, &meta_for(&a));
        }
        let a0 = acc(lines[0], 2);
        let lvl = h.access(&a0, &meta_for(&a0));
        assert_eq!(lvl, ServiceLevel::L2, "evicted from L1 but resident in L2");
    }

    #[test]
    fn latency_accumulates_and_amat_sane() {
        let mut h = Hierarchy::new(small(), "lru");
        let a = acc(0x2000, 3);
        h.access(&a, &meta_for(&a)); // DRAM
        h.access(&a, &meta_for(&a)); // L1
        let dram = h.latency_of(ServiceLevel::Dram);
        let l1 = h.latency_of(ServiceLevel::L1);
        assert_eq!(h.total_latency, dram + l1);
        assert!((h.amat() - (dram + l1) as f64 / 2.0).abs() < 1e-9);
    }

    #[test]
    fn prefetcher_fills_l2() {
        let mut cfg = small();
        cfg.prefetcher = "nextline".into();
        let mut h = Hierarchy::new(cfg, "lru");
        let a = acc(0x4000, 4);
        h.access(&a, &meta_for(&a)); // miss → prefetch lines +1,+2
        assert!(h.l2.stats.prefetch_fills >= 1);
        // The next line should now hit in L2 (useful prefetch).
        let b = acc(0x4000 + 64, 4);
        let lvl = h.access(&b, &meta_for(&b));
        assert_eq!(lvl, ServiceLevel::L2);
        assert_eq!(h.l2.stats.prefetch_useful, 1);
    }

    #[test]
    fn policy_under_test_sits_at_l2() {
        let h = Hierarchy::new(small(), "acpc");
        assert_eq!(h.policy_name(), "acpc");
        assert_eq!(h.l1.policy_name(), "lru");
        assert_eq!(h.l3.policy_name(), "drrip");
    }

    #[test]
    fn presets_exist() {
        assert!(HierarchyConfig::by_name("scaled").is_some());
        assert!(HierarchyConfig::by_name("epyc7763").is_some());
        assert!(HierarchyConfig::by_name("x").is_none());
    }

    #[test]
    fn shard_validation_and_geometry() {
        let cfg = HierarchyConfig::scaled();
        // Scaled L1 = 16 KiB / 8-way → 32 sets: up to 32 shards divide all
        // levels.
        for shards in [1usize, 2, 8, 32] {
            assert!(cfg.validate_shards(shards).is_ok(), "{shards}");
        }
        assert!(cfg.validate_shards(0).is_err());
        assert!(cfg.validate_shards(3).is_err(), "non-power-of-two rejected");
        assert!(cfg.validate_shards(64).is_err(), "exceeds L1 set count");

        // A shard owns 1/N of each level's sets.
        let h = Hierarchy::new_sharded(small(), "lru", 1, 4);
        let full = Hierarchy::new(small(), "lru");
        assert_eq!(h.l2.num_sets() * 4, full.l2.num_sets());
        assert_eq!(h.l1.num_sets() * 4, full.l1.num_sets());
    }

    #[test]
    fn sharded_hierarchy_serves_its_partition() {
        // Shard 2 of 4 owns lines ≡ 2 (mod 4); drive a few of its lines and
        // check the usual climb-the-hierarchy behavior within the shard.
        let mut h = Hierarchy::new_sharded(small(), "lru", 2, 4);
        let line = 0x1000 / 64 * 4 + 2; // line ≡ 2 (mod 4)
        let a = acc(line << 6, 1);
        assert_eq!(h.access(&a, &meta_for(&a)), ServiceLevel::Dram);
        assert_eq!(h.access(&a, &meta_for(&a)), ServiceLevel::L1);
    }

    #[test]
    fn cross_shard_prefetch_candidates_dropped() {
        let mut cfg = small();
        cfg.prefetcher = "nextline".into();
        // Shard 0 of 4: next-line candidates (line+1, line+2) are ≡ 1, 2
        // (mod 4) — never shard 0's — so every candidate must be dropped.
        let mut h = Hierarchy::new_sharded(cfg, "lru", 0, 4);
        let line = 32u64; // ≡ 0 (mod 4) → owned by shard 0
        let a = acc(line << 6, 4);
        h.access(&a, &meta_for(&a));
        assert_eq!(h.l2.stats.prefetch_fills, 0, "no in-shard candidates");
        assert!(h.cross_shard_prefetches_dropped >= 1);
    }

    #[test]
    fn throttle_raises_filter_threshold_and_restores_it() {
        // ACPC: base 0.22 doubles (clamped up to 0.5) under throttle.
        let mut h = Hierarchy::new(small(), "acpc");
        let base = h.prefetch_filter_threshold;
        assert_eq!(base, Some(0.22));
        h.set_prefetch_throttled(true);
        assert!(h.prefetch_throttled());
        assert_eq!(h.prefetch_filter_threshold, Some(0.5));
        h.set_prefetch_throttled(true); // idempotent
        assert_eq!(h.prefetch_filter_threshold, Some(0.5));
        h.set_prefetch_throttled(false);
        assert!(!h.prefetch_throttled());
        assert_eq!(h.prefetch_filter_threshold, base);

        // Unfiltered policies get a filter installed for the throttle's
        // duration, and go back to unfiltered afterwards.
        let mut h = Hierarchy::new(small(), "lru");
        assert_eq!(h.prefetch_filter_threshold, None);
        h.set_prefetch_throttled(true);
        assert_eq!(h.prefetch_filter_threshold, Some(0.5));
        h.set_prefetch_throttled(false);
        assert_eq!(h.prefetch_filter_threshold, None);
    }

    #[test]
    fn presets_validate_and_bad_geometry_names_the_level() {
        assert!(HierarchyConfig::scaled().validate().is_ok());
        assert!(HierarchyConfig::epyc7763().validate().is_ok());
        let mut c = HierarchyConfig::scaled();
        c.l2.size_bytes = 96 * 1024; // 192 sets — not a power of two
        let e = c.validate().unwrap_err();
        assert!(e.contains("L2"), "{e}");
    }
}
