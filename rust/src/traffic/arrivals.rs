//! Open-loop arrival processes and the admission-queued workload wrapper.
//!
//! A closed-loop generator admits a new session the moment a slot frees,
//! so offered load tracks service capacity and the system can never be
//! overloaded. [`OpenLoopWorkload`] breaks that coupling: an
//! [`ArrivalProcess`] injects requests on its own virtual clock (one tick
//! per engine access), arrivals wait in a bounded FIFO admission queue,
//! and when the queue is full further arrivals are *shed*. The inner
//! workload (its autonomous arrivals disabled) only receives sessions via
//! [`crate::trace::Workload::force_arrival`] — the same externally-driven
//! admission path the serving coordinator uses — so queue delay, offered
//! vs served throughput, and shed counts become measurable
//! ([`TrafficSummary`]).
//!
//! Determinism: the process draws from its own [`Xoshiro256`] stream,
//! never from the inner generator's, so attaching an arrival process does
//! not perturb the per-session access pattern, and a fixed seed produces
//! one arrival history regardless of shard or thread count (the wrapper
//! always runs on the single producer thread).

use super::TrafficSummary;
use crate::trace::{Access, Workload};
use crate::util::rng::Xoshiro256;
use anyhow::{bail, Result};
use std::collections::VecDeque;

/// The supported arrival-process shapes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrivalKind {
    /// Homogeneous Poisson arrivals at a constant mean rate.
    Poisson,
    /// Sinusoidal rate curve (a compressed diurnal cycle): the mean rate
    /// swings by `amplitude` around the base over one `period`.
    Diurnal,
    /// Two-state on/off modulated Poisson process (MMPP-style): a hidden
    /// burst state toggles between a hot rate (`rate × burst_factor`) and
    /// a cold rate (`rate × OFF_FACTOR`).
    Bursty,
}

impl ArrivalKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "poisson" => Self::Poisson,
            "diurnal" => Self::Diurnal,
            "bursty" => Self::Bursty,
            other => bail!("unknown arrival process '{other}' (poisson|diurnal|bursty)"),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            Self::Poisson => "poisson",
            Self::Diurnal => "diurnal",
            Self::Bursty => "bursty",
        }
    }
}

/// Cold-state rate multiplier of the bursty process.
const OFF_FACTOR: f64 = 0.25;

/// Everything an [`OpenLoopWorkload`] needs besides its inner workload.
#[derive(Debug, Clone, PartialEq)]
pub struct OpenLoopConfig {
    pub kind: ArrivalKind,
    /// Mean offered rate, in requests per 1000 access ticks.
    pub rate: f64,
    /// Diurnal cycle length in ticks.
    pub period: u64,
    /// Diurnal swing as a fraction of the base rate, in `[0, 1]`.
    pub amplitude: f64,
    /// Hot-state rate multiplier of the bursty process (> 1 = overload
    /// bursts).
    pub burst_factor: f64,
    /// Per-tick probability of toggling the bursty hidden state.
    pub burst_switch_p: f64,
    /// Admission-queue capacity; arrivals beyond it are shed.
    pub queue_depth: usize,
    /// Seed of the process' private RNG stream.
    pub seed: u64,
}

impl OpenLoopConfig {
    pub fn new(kind: ArrivalKind, seed: u64) -> Self {
        Self {
            kind,
            rate: 4.0,
            period: 20_000,
            amplitude: 0.6,
            burst_factor: 4.0,
            burst_switch_p: 0.002,
            queue_depth: 32,
            seed,
        }
    }

    /// The registry `bursty-batch` scenario: on/off bursts whose hot state
    /// offers well above service capacity, so the queue fills and sheds.
    pub fn bursty_batch(seed: u64) -> Self {
        Self::new(ArrivalKind::Bursty, seed)
    }

    pub fn validate(&self) -> Result<()> {
        if !(self.rate.is_finite() && self.rate > 0.0) {
            bail!("arrival rate must be finite and > 0 (got {})", self.rate);
        }
        if self.period == 0 {
            bail!("diurnal period must be >= 1 tick");
        }
        if !(0.0..=1.0).contains(&self.amplitude) {
            bail!("diurnal amplitude must be in [0, 1] (got {})", self.amplitude);
        }
        if !(self.burst_factor.is_finite() && self.burst_factor > 0.0) {
            bail!("burst factor must be finite and > 0 (got {})", self.burst_factor);
        }
        if !(0.0..=1.0).contains(&self.burst_switch_p) {
            bail!("burst switch probability must be in [0, 1] (got {})", self.burst_switch_p);
        }
        if self.queue_depth == 0 {
            bail!("admission queue depth must be >= 1");
        }
        Ok(())
    }
}

/// A seeded arrival process over a virtual tick clock.
#[derive(Debug, Clone)]
pub struct ArrivalProcess {
    cfg: OpenLoopConfig,
    rng: Xoshiro256,
    /// Hidden state of the bursty process.
    hot: bool,
}

impl ArrivalProcess {
    pub fn new(cfg: OpenLoopConfig) -> Self {
        let rng = Xoshiro256::new(cfg.seed);
        // Bursty starts hot so even short runs exercise the overload path
        // (and the first arrivals land early regardless of seed).
        let hot = cfg.kind == ArrivalKind::Bursty;
        Self { cfg, rng, hot }
    }

    /// The instantaneous mean rate (requests per 1000 ticks) at `tick`.
    pub fn rate_at(&self, tick: u64) -> f64 {
        let base = self.cfg.rate;
        match self.cfg.kind {
            ArrivalKind::Poisson => base,
            ArrivalKind::Diurnal => {
                let frac = (tick % self.cfg.period) as f64 / self.cfg.period as f64;
                base * (1.0 + self.cfg.amplitude * (frac * std::f64::consts::TAU).sin())
            }
            ArrivalKind::Bursty => {
                if self.hot {
                    base * self.cfg.burst_factor
                } else {
                    base * OFF_FACTOR
                }
            }
        }
    }

    /// Advance one tick and sample how many requests arrive during it.
    pub fn step(&mut self, tick: u64) -> u64 {
        if self.cfg.kind == ArrivalKind::Bursty && self.rng.chance(self.cfg.burst_switch_p) {
            self.hot = !self.hot;
        }
        let lambda = self.rate_at(tick) / 1000.0;
        if lambda <= 0.0 {
            return 0;
        }
        self.rng.next_poisson(lambda)
    }
}

/// A closed-loop workload driven open-loop: arrivals at an offered rate,
/// a bounded admission queue in front of the session slots, and shed on
/// overflow. Implements [`Workload`], so it runs through the engine, the
/// sharded path, sweeps, and the farm unchanged.
pub struct OpenLoopWorkload {
    name: String,
    inner: Box<dyn Workload>,
    process: ArrivalProcess,
    /// Enqueue tick of each waiting request (FIFO).
    queue: VecDeque<u64>,
    queue_depth: usize,
    tick: u64,
    summary: TrafficSummary,
}

impl OpenLoopWorkload {
    /// Wrap `inner` (which must have autonomous arrivals disabled — all
    /// admission flows through `force_arrival`). `name` overrides the
    /// reported workload name; `None` keeps the inner one.
    pub fn new(inner: Box<dyn Workload>, cfg: OpenLoopConfig, name: Option<&str>) -> Self {
        let name = name.map(str::to_string).unwrap_or_else(|| inner.name());
        let queue_depth = cfg.queue_depth;
        Self {
            name,
            inner,
            process: ArrivalProcess::new(cfg),
            queue: VecDeque::new(),
            queue_depth,
            tick: 0,
            summary: TrafficSummary::default(),
        }
    }

    /// The traffic counters accumulated so far (`served` tracks the inner
    /// workload's completed sessions).
    pub fn summary(&self) -> TrafficSummary {
        let mut s = self.summary;
        s.served = self.inner.sessions_completed();
        s
    }

    /// One virtual tick: sample arrivals, shed on overflow, then admit
    /// from the queue head while the inner workload has free capacity.
    fn advance(&mut self) {
        self.tick += 1;
        let arrivals = self.process.step(self.tick);
        for _ in 0..arrivals {
            self.summary.offered += 1;
            if self.queue.len() < self.queue_depth {
                self.queue.push_back(self.tick);
            } else {
                self.summary.shed += 1;
            }
        }
        self.summary.queue_peak = self.summary.queue_peak.max(self.queue.len() as u64);
        while let Some(&enqueued) = self.queue.front() {
            if !self.inner.force_arrival() {
                break;
            }
            self.queue.pop_front();
            let delay = self.tick - enqueued;
            self.summary.admitted += 1;
            self.summary.queue_delay_sum += delay;
            self.summary.queue_delay_max = self.summary.queue_delay_max.max(delay);
        }
    }
}

impl Workload for OpenLoopWorkload {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn next_access(&mut self) -> Access {
        self.advance();
        self.inner.next_access()
    }

    fn tokens_done(&self) -> u64 {
        self.inner.tokens_done()
    }

    fn sessions_completed(&self) -> u64 {
        self.inner.sessions_completed()
    }

    fn live_sessions(&self) -> usize {
        self.inner.live_sessions()
    }

    fn has_work(&self) -> bool {
        self.inner.has_work() || !self.queue.is_empty()
    }

    /// External admission bypasses the queue (the serving coordinator
    /// routes its own arrivals); open-loop runs never call this.
    fn force_arrival(&mut self) -> bool {
        self.inner.force_arrival()
    }

    fn traffic(&self) -> Option<TrafficSummary> {
        Some(self.summary())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{GeneratorConfig, TraceGenerator};

    fn open_loop(seed: u64, kind: ArrivalKind) -> OpenLoopWorkload {
        let mut g = GeneratorConfig::tiny(seed);
        g.arrival_p_hot = 0.0;
        g.arrival_p_cold = 0.0;
        let mut cfg = OpenLoopConfig::new(kind, seed);
        cfg.rate = 8.0;
        cfg.queue_depth = 4;
        OpenLoopWorkload::new(Box::new(TraceGenerator::new(g)), cfg, Some("open-loop-test"))
    }

    #[test]
    fn arrivals_are_seed_deterministic() {
        for kind in [ArrivalKind::Poisson, ArrivalKind::Diurnal, ArrivalKind::Bursty] {
            let a = open_loop(11, kind).generate(6_000);
            let b = open_loop(11, kind).generate(6_000);
            assert_eq!(a, b, "{kind:?} must be deterministic");
            let c = open_loop(12, kind).generate(6_000);
            assert_ne!(a, c, "{kind:?} must vary with the seed");
        }
    }

    #[test]
    fn queue_admits_sheds_and_accounts_delay() {
        let mut w = open_loop(7, ArrivalKind::Bursty);
        let _ = w.generate(30_000);
        let t = w.traffic().expect("open-loop workloads report traffic");
        assert!(t.offered > 0, "arrivals must occur: {t:?}");
        assert!(t.admitted > 0, "some requests must be admitted: {t:?}");
        assert!(t.admitted + t.shed <= t.offered);
        assert!(t.queue_delay_max >= t.queue_delay_mean() as u64);
        assert!(t.queue_peak as usize <= 4, "queue is bounded: {t:?}");
        assert!(w.tokens_done() > 0, "admitted sessions must decode tokens");
    }

    #[test]
    fn diurnal_rate_swings_around_base() {
        let mut cfg = OpenLoopConfig::new(ArrivalKind::Diurnal, 1);
        cfg.rate = 10.0;
        cfg.amplitude = 0.5;
        cfg.period = 1000;
        let p = ArrivalProcess::new(cfg);
        let peak = p.rate_at(250); // sin peak
        let trough = p.rate_at(750); // sin trough
        assert!(peak > 14.0 && peak < 16.0, "peak {peak}");
        assert!(trough > 4.0 && trough < 6.0, "trough {trough}");
        assert!((p.rate_at(0) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn config_validation_rejects_nonsense() {
        let ok = OpenLoopConfig::new(ArrivalKind::Poisson, 0);
        assert!(ok.validate().is_ok());
        let mut bad = ok.clone();
        bad.rate = 0.0;
        assert!(bad.validate().is_err());
        let mut bad = ok.clone();
        bad.amplitude = 1.5;
        assert!(bad.validate().is_err());
        let mut bad = ok.clone();
        bad.queue_depth = 0;
        assert!(bad.validate().is_err());
        let mut bad = ok;
        bad.period = 0;
        assert!(bad.validate().is_err());
    }
}
