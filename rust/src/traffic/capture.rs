//! Capture sink: record the access stream a serve run actually produced
//! into a v2 `.acpctrace` for offline, bit-for-bit replay.
//!
//! The coordinator's workers each feed their accesses (with a per-worker
//! arrival counter) into per-worker buffers; at shutdown the coordinator
//! concatenates them in worker order into one sink and writes the file.
//! Worker index doubles as the tenant id, so `acpc trace-stats` can show
//! the per-tenant breakdown of a capture.

use crate::trace::file::{write_trace_v2, TraceRecord};
use crate::trace::Access;
use anyhow::Result;
use std::path::Path;

/// Accumulates [`TraceRecord`]s plus the token/session totals that go in
/// the v2 header.
#[derive(Debug, Clone, Default)]
pub struct CaptureSink {
    records: Vec<TraceRecord>,
    tokens: u64,
    sessions: u64,
}

impl CaptureSink {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one access with its provenance.
    pub fn record(&mut self, access: Access, tenant: u32, arrival: u64) {
        self.records.push(TraceRecord { access, tenant, arrival });
    }

    /// Set the header totals (decoded tokens, completed sessions).
    pub fn set_totals(&mut self, tokens: u64, sessions: u64) {
        self.tokens = tokens;
        self.sessions = sessions;
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn records(&self) -> &[TraceRecord] {
        &self.records
    }

    /// Write the capture as a v2 `.acpctrace`.
    pub fn finish(&self, path: &Path) -> Result<()> {
        write_trace_v2(path, &self.records, self.tokens, self.sessions)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::file::TraceReader;
    use crate::trace::{GeneratorConfig, TraceGenerator};

    #[test]
    fn sink_writes_a_readable_v2_capture() {
        let trace = TraceGenerator::new(GeneratorConfig::tiny(3)).generate(500);
        let mut sink = CaptureSink::new();
        assert!(sink.is_empty());
        for (i, &a) in trace.iter().enumerate() {
            sink.record(a, (i % 3) as u32, i as u64);
        }
        sink.set_totals(123, 9);
        assert_eq!(sink.len(), 500);

        let dir = std::env::temp_dir().join("acpc_capture_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cap.acpctrace");
        sink.finish(&path).unwrap();

        let rd = TraceReader::open(&path).unwrap();
        assert_eq!(rd.version(), 2);
        assert_eq!(rd.count(), 500);
        assert_eq!((rd.tokens(), rd.sessions()), (123, 9));
        let back: Vec<TraceRecord> = rd.map(|r| r.unwrap()).collect();
        assert_eq!(back, sink.records());
        std::fs::remove_file(&path).unwrap();
    }
}
