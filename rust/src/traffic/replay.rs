//! Streaming replay of a captured `.acpctrace` through the [`Workload`]
//! trait: serve-mode regressions become reproducible offline, bit-for-bit,
//! via the ordinary `acpc run` / farm / store machinery.

use crate::trace::file::{TraceReader, TraceRecord};
use crate::trace::{Access, Workload};
use anyhow::{bail, Context, Result};
use std::collections::VecDeque;
use std::path::{Path, PathBuf};

/// Records pulled from the file per refill; keeps memory flat no matter
/// how large the capture is.
const CHUNK: usize = 4096;

/// A [`Workload`] that replays a `.acpctrace` (v1 or v2) in file order.
///
/// The stream wraps around when the capture is exhausted (the `Workload`
/// contract is an infinite stream), so a run of exactly `count()` accesses
/// reproduces the capture bit-for-bit and longer runs loop it.
/// [`Workload::tokens_done`] scales the header's token total by replay
/// progress (v1 files carry no totals and report 0). The header is
/// validated at [`open`](Self::open); a file that turns corrupt or
/// truncated mid-replay panics, since `next_access` cannot surface errors.
pub struct ReplayWorkload {
    path: PathBuf,
    name: String,
    count: u64,
    total_tokens: u64,
    total_sessions: u64,
    reader: TraceReader,
    buf: VecDeque<TraceRecord>,
    /// Records handed out so far, monotone across wrap-arounds.
    consumed: u64,
}

impl ReplayWorkload {
    pub fn open(path: &Path) -> Result<Self> {
        let reader = TraceReader::open(path)?;
        if reader.count() == 0 {
            bail!("cannot replay empty trace {path:?}");
        }
        let stem = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| path.display().to_string());
        Ok(Self {
            path: path.to_path_buf(),
            name: format!("replay:{stem}"),
            count: reader.count(),
            total_tokens: reader.tokens(),
            total_sessions: reader.sessions(),
            reader,
            buf: VecDeque::with_capacity(CHUNK),
            consumed: 0,
        })
    }

    /// Records in the underlying capture (one full pass of the stream).
    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Header totals scaled by replay progress; exact at whole passes.
    fn scaled(&self, total: u64) -> u64 {
        (total as u128 * self.consumed as u128 / self.count as u128) as u64
    }

    fn refill(&mut self) {
        while self.buf.is_empty() {
            for rec in self.reader.by_ref().take(CHUNK) {
                let rec = rec
                    .with_context(|| format!("replaying {:?}", self.path))
                    .expect("capture became unreadable mid-replay");
                self.buf.push_back(rec);
            }
            if self.buf.is_empty() {
                // Exhausted: wrap around by reopening.
                self.reader = TraceReader::open(&self.path)
                    .expect("capture disappeared mid-replay");
            }
        }
    }
}

impl Workload for ReplayWorkload {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn next_access(&mut self) -> Access {
        if self.buf.is_empty() {
            self.refill();
        }
        self.consumed += 1;
        self.buf.pop_front().expect("refill guarantees a record").access
    }

    fn tokens_done(&self) -> u64 {
        self.scaled(self.total_tokens)
    }

    fn sessions_completed(&self) -> u64 {
        self.scaled(self.total_sessions)
    }

    fn live_sessions(&self) -> usize {
        0
    }

    fn has_work(&self) -> bool {
        true
    }

    fn force_arrival(&mut self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::file::write_trace_v2;
    use crate::trace::{GeneratorConfig, TraceGenerator};

    fn capture_file(n: usize, tokens: u64, sessions: u64) -> PathBuf {
        let trace = TraceGenerator::new(GeneratorConfig::tiny(17)).generate(n);
        let records: Vec<TraceRecord> = trace
            .iter()
            .enumerate()
            .map(|(i, &access)| TraceRecord { access, tenant: (i % 4) as u32, arrival: i as u64 })
            .collect();
        let dir = std::env::temp_dir().join("acpc_replay_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("replay_{n}.acpctrace"));
        write_trace_v2(&path, &records, tokens, sessions).unwrap();
        path
    }

    #[test]
    fn replay_reproduces_the_capture_bit_for_bit() {
        let path = capture_file(3_000, 900, 30);
        let expected = crate::trace::file::read_trace(&path).unwrap();
        let mut w = ReplayWorkload::open(&path).unwrap();
        assert_eq!(w.count(), 3_000);
        let replayed = w.generate(3_000);
        assert_eq!(replayed, expected);
        assert_eq!(w.tokens_done(), 900);
        assert_eq!(w.sessions_completed(), 30);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn replay_wraps_around_and_keeps_counting() {
        let path = capture_file(400, 100, 8);
        let expected = crate::trace::file::read_trace(&path).unwrap();
        let mut w = ReplayWorkload::open(&path).unwrap();
        let two_passes = w.generate(800);
        assert_eq!(&two_passes[..400], &expected[..]);
        assert_eq!(&two_passes[400..], &expected[..]);
        assert_eq!(w.tokens_done(), 200, "tokens scale with wrapped progress");
        assert!(w.has_work());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn replay_rejects_empty_and_missing_files() {
        let dir = std::env::temp_dir().join("acpc_replay_test");
        std::fs::create_dir_all(&dir).unwrap();
        let empty = dir.join("empty.acpctrace");
        write_trace_v2(&empty, &[], 0, 0).unwrap();
        assert!(ReplayWorkload::open(&empty).is_err());
        assert!(ReplayWorkload::open(&dir.join("nope.acpctrace")).is_err());
        std::fs::remove_file(&empty).unwrap();
    }

    #[test]
    fn replay_is_boxable_as_a_workload() {
        let path = capture_file(50, 10, 1);
        let mut boxed: Box<dyn Workload> = Box::new(ReplayWorkload::open(&path).unwrap());
        assert!(boxed.name().starts_with("replay:"));
        assert_eq!(boxed.live_sessions(), 0);
        assert!(!boxed.force_arrival());
        let _ = boxed.next_access();
        std::fs::remove_file(&path).unwrap();
    }
}
