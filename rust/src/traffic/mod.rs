//! Population-scale traffic: open-loop arrivals, tenant churn, and
//! serve-trace capture/replay.
//!
//! Every scenario in [`crate::trace::scenario`] was historically a
//! *closed-loop* generator: a session departs, a slot frees, the generator
//! immediately admits the next arrival, so offered load always equals
//! service capacity and overload is unobservable. This module decouples
//! the two sides:
//!
//! - [`arrivals`] — seeded-deterministic arrival processes (Poisson,
//!   diurnal rate curve, bursty on/off MMPP) driving an
//!   [`OpenLoopWorkload`]: requests arrive at an *offered* rate, wait in a
//!   bounded admission queue, and are shed when it overflows. Queue delay,
//!   offered-vs-served throughput and shed counts surface as a
//!   [`TrafficSummary`] in the run report.
//! - [`population`] — a tenant population with churn, per-tenant
//!   Zipf-distributed address footprints, and a shared system-prompt
//!   prefix block whose cross-tenant reuse (and pollution) the
//!   `prefix-share` scenario makes measurable.
//! - [`capture`] / [`replay`] — a sink recording the access stream the
//!   serve coordinator *actually produced* into a v2 `.acpctrace`
//!   (tenant id + arrival timestamp per record), and a streaming
//!   [`ReplayWorkload`] that plays a capture back bit-for-bit through
//!   [`crate::api::Runner`], making serve-mode regressions reproducible
//!   offline.
//!
//! Open-loop counters are **shard- and thread-count invariant by
//! construction**: the workload always runs on exactly one thread — inline
//! in the single-threaded engine, producer-side in the sharded path — so a
//! fixed seed yields one arrival/admission/shed history regardless of how
//! the access stream is partitioned downstream
//! (`tests/integration_traffic.rs` asserts this).

pub mod arrivals;
pub mod capture;
pub mod population;
pub mod replay;

pub use arrivals::{ArrivalKind, ArrivalProcess, OpenLoopConfig, OpenLoopWorkload};
pub use capture::CaptureSink;
pub use population::{PopulationConfig, PopulationWorkload, SHARED_PREFIX_BASE};
pub use replay::ReplayWorkload;

use crate::util::json::{Json, JsonError};

/// Open-loop traffic counters harvested from a workload after a run.
///
/// All counters are monotone and fully determined by the workload seed
/// (the arrival process never observes wall-clock time or thread
/// scheduling), so two runs of the same spec report identical summaries.
/// Time is measured in *access ticks* — one tick per access the engine
/// drives — the same virtual clock the generator stamps into
/// [`crate::trace::Access::time`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct TrafficSummary {
    /// Requests the arrival process generated (offered load).
    pub offered: u64,
    /// Requests admitted into a session slot.
    pub admitted: u64,
    /// Requests dropped because the admission queue was full (overload).
    pub shed: u64,
    /// Sessions fully served (completed) by the inner workload.
    pub served: u64,
    /// Total ticks admitted requests spent queued before admission.
    pub queue_delay_sum: u64,
    /// Worst single queueing delay (ticks).
    pub queue_delay_max: u64,
    /// Peak admission-queue depth observed.
    pub queue_peak: u64,
}

impl TrafficSummary {
    /// Mean queueing delay (ticks) over admitted requests.
    pub fn queue_delay_mean(&self) -> f64 {
        if self.admitted == 0 {
            0.0
        } else {
            self.queue_delay_sum as f64 / self.admitted as f64
        }
    }

    /// Fraction of offered requests shed by the bounded queue.
    pub fn shed_frac(&self) -> f64 {
        if self.offered == 0 {
            0.0
        } else {
            self.shed as f64 / self.offered as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("offered", Json::Num(self.offered as f64)),
            ("admitted", Json::Num(self.admitted as f64)),
            ("shed", Json::Num(self.shed as f64)),
            ("served", Json::Num(self.served as f64)),
            ("queue_delay_sum", Json::Num(self.queue_delay_sum as f64)),
            ("queue_delay_max", Json::Num(self.queue_delay_max as f64)),
            ("queue_peak", Json::Num(self.queue_peak as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self, JsonError> {
        let u = |k: &str| -> Result<u64, JsonError> {
            Ok(j.req(k)?.as_f64().unwrap_or(0.0).max(0.0) as u64)
        };
        Ok(Self {
            offered: u("offered")?,
            admitted: u("admitted")?,
            shed: u("shed")?,
            served: u("served")?,
            queue_delay_sum: u("queue_delay_sum")?,
            queue_delay_max: u("queue_delay_max")?,
            queue_peak: u("queue_peak")?,
        })
    }

    /// One-line human rendering for `acpc run` output.
    pub fn summary_line(&self) -> String {
        format!(
            "traffic: offered={} admitted={} shed={} ({:.1}%) served={} \
             queue_delay mean={:.1} max={} peak_depth={}",
            self.offered,
            self.admitted,
            self.shed,
            self.shed_frac() * 100.0,
            self.served,
            self.queue_delay_mean(),
            self.queue_delay_max,
            self.queue_peak
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_json_roundtrips() {
        let t = TrafficSummary {
            offered: 120,
            admitted: 100,
            shed: 20,
            served: 88,
            queue_delay_sum: 4200,
            queue_delay_max: 311,
            queue_peak: 17,
        };
        let j = t.to_json();
        let back = TrafficSummary::from_json(&j).unwrap();
        assert_eq!(t, back);
        assert_eq!(j.to_pretty(), back.to_json().to_pretty());
        assert!((t.queue_delay_mean() - 42.0).abs() < 1e-9);
        assert!((t.shed_frac() - 20.0 / 120.0).abs() < 1e-12);
        assert!(t.summary_line().contains("offered=120"));
    }

    #[test]
    fn empty_summary_has_safe_rates() {
        let t = TrafficSummary::default();
        assert_eq!(t.queue_delay_mean(), 0.0);
        assert_eq!(t.shed_frac(), 0.0);
    }
}
