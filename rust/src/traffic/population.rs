//! Tenant-population workload: arrival/churn of tenants over time,
//! per-tenant Zipf-distributed address footprints, and a shared
//! system-prompt prefix block reused across every tenant.
//!
//! The closed-loop scenario generators model one serving node's session
//! mix; this workload models the *population* above it. Each tenant owns
//! a private KV footprint (Zipf-skewed sizes across tenants, Zipf-skewed
//! line popularity within a footprint) at a tenant-unique address base, so
//! tenant churn — a slot being recycled to a fresh tenant id — turns a
//! warm footprint cold exactly the way a new customer's traffic does.
//! Every session additionally scans the **shared system-prompt prefix**
//! block ([`SHARED_PREFIX_BASE`]) during prefill and keeps re-reading it
//! while decoding: those lines are the only cross-tenant reuse in the
//! stream, which is what makes prefix-cache sharing (and the pollution
//! one-shot tenants inflict on it) measurable — the registered
//! `prefix-share` scenario.
//!
//! Session ids encode their tenant (`tenant_id % 2^16` in the high half),
//! so a trace alone is enough to attribute accesses to tenants.

use crate::trace::generator::LINE;
use crate::trace::{region, Access, StreamKind, Workload};
use crate::util::rng::{Xoshiro256, Zipf};
use std::collections::VecDeque;

/// First byte of the shared system-prompt prefix block (KV region).
pub const SHARED_PREFIX_BASE: u64 = region::KV;

/// Per-tenant address stride (64 MiB): footprints never overlap.
const TENANT_STRIDE: u64 = 1 << 26;

/// Tenant ids wrap for addressing after this many (keeps every footprint
/// inside the KV region); churn histories longer than this reuse bases.
const MAX_TENANT_BASES: u32 = 1 << 13;

/// Append-ring length (lines) for per-tenant KV writes.
const APPEND_RING: u64 = 1 << 12;

/// Prefill scans at most this many shared-prefix lines per admission.
const PREFIX_SCAN: u64 = 48;

#[derive(Debug, Clone)]
pub struct PopulationConfig {
    pub seed: u64,
    /// Concurrently active tenants.
    pub tenant_slots: usize,
    /// Per-token probability a tenant slot churns to a fresh tenant.
    pub churn_p: f64,
    /// Largest per-tenant footprint (lines); sizes are Zipf-skewed below it.
    pub footprint_lines_max: u64,
    /// Zipf skew of line popularity inside one tenant's footprint.
    pub footprint_theta: f64,
    /// Zipf skew of which tenant a new session belongs to.
    pub tenant_select_theta: f64,
    /// Shared system-prompt prefix size (lines).
    pub shared_prefix_lines: u64,
    /// Probability a decode-time KV read hits the shared prefix.
    pub prefix_read_p: f64,
    /// KV reads per decoded token.
    pub reads_per_token: usize,
    /// Concurrent session cap.
    pub max_live_sessions: usize,
    /// Mean session length (tokens, exponential).
    pub session_tokens_mean: f64,
    /// Per-token probability of a new session arriving (closed-loop).
    pub arrival_p: f64,
}

impl PopulationConfig {
    /// The registry `prefix-share` scenario parameters.
    pub fn prefix_share(seed: u64) -> Self {
        Self {
            seed,
            tenant_slots: 8,
            churn_p: 0.002,
            footprint_lines_max: 1 << 13,
            footprint_theta: 0.9,
            tenant_select_theta: 1.2,
            shared_prefix_lines: 384,
            prefix_read_p: 0.3,
            reads_per_token: 8,
            max_live_sessions: 12,
            session_tokens_mean: 48.0,
            arrival_p: 0.08,
        }
    }
}

#[derive(Debug, Clone)]
struct Tenant {
    id: u32,
    /// Footprint size in lines (Zipf-skewed across tenants).
    footprint: u64,
    /// Line popularity inside the footprint.
    zipf: Zipf,
    /// KV-append cursor (ring beyond the footprint).
    append: u64,
}

impl Tenant {
    fn base(&self) -> u64 {
        region::KV + (1 + (self.id % MAX_TENANT_BASES)) as u64 * TENANT_STRIDE
    }
}

#[derive(Debug, Clone)]
struct Sess {
    id: u32,
    slot: usize,
    ctx: u32,
    tokens_left: u32,
}

/// The population [`Workload`]: self-contained (no [`super::arrivals`]
/// wrapper needed) and seed-deterministic.
pub struct PopulationWorkload {
    name: String,
    cfg: PopulationConfig,
    rng: Xoshiro256,
    tenants: Vec<Tenant>,
    sessions: Vec<Sess>,
    tenant_select: Zipf,
    prefix_zipf: Zipf,
    embed_zipf: Zipf,
    footprint_rank: Zipf,
    pending: VecDeque<Access>,
    time: u64,
    scratch_head: u64,
    next_tenant_id: u32,
    session_counter: u32,
    tokens_done: u64,
    sessions_completed: u64,
}

impl PopulationWorkload {
    pub fn new(cfg: PopulationConfig) -> Self {
        Self::with_name(cfg, "population")
    }

    pub fn with_name(cfg: PopulationConfig, name: &str) -> Self {
        assert!(cfg.tenant_slots > 0, "need at least one tenant slot");
        assert!(cfg.shared_prefix_lines > 0, "need a shared prefix block");
        assert!(cfg.reads_per_token > 0 && cfg.max_live_sessions > 0);
        let mut rng = Xoshiro256::new(cfg.seed);
        let footprint_rank = Zipf::new(64, 1.1);
        let mut next_tenant_id = 0u32;
        let mut tenants = Vec::with_capacity(cfg.tenant_slots);
        for _ in 0..cfg.tenant_slots {
            tenants.push(Self::fresh_tenant(&cfg, &footprint_rank, &mut rng, &mut next_tenant_id));
        }
        let tenant_select = Zipf::new(cfg.tenant_slots as u64, cfg.tenant_select_theta);
        let prefix_zipf = Zipf::new(cfg.shared_prefix_lines, 1.1);
        let embed_zipf = Zipf::new(50_000, 0.95);
        Self {
            name: name.to_string(),
            cfg,
            rng,
            tenants,
            sessions: Vec::new(),
            tenant_select,
            prefix_zipf,
            embed_zipf,
            footprint_rank,
            pending: VecDeque::new(),
            time: 0,
            scratch_head: 0,
            next_tenant_id,
            session_counter: 0,
            tokens_done: 0,
            sessions_completed: 0,
        }
    }

    pub fn tokens_done(&self) -> u64 {
        self.tokens_done
    }

    pub fn sessions_completed(&self) -> u64 {
        self.sessions_completed
    }

    /// Active tenant ids (for tests / characterization).
    pub fn tenant_ids(&self) -> Vec<u32> {
        self.tenants.iter().map(|t| t.id).collect()
    }

    fn fresh_tenant(
        cfg: &PopulationConfig,
        footprint_rank: &Zipf,
        rng: &mut Xoshiro256,
        next_id: &mut u32,
    ) -> Tenant {
        let id = *next_id;
        *next_id += 1;
        // Zipf-skewed footprint sizes: a few whales, a long tail of small
        // tenants (floor keeps the within-tenant Zipf meaningful).
        let rank = footprint_rank.sample(rng);
        let footprint = (cfg.footprint_lines_max / (1 + rank)).max(64);
        Tenant { id, footprint, zipf: Zipf::new(footprint, cfg.footprint_theta), append: 0 }
    }

    fn pc(kind: StreamKind, site: u64) -> u64 {
        ((kind as u64) << 32) | site
    }

    fn push(&mut self, s: &Sess, kind: StreamKind, addr: u64, site: u64, is_write: bool) {
        self.time += 1;
        self.pending.push_back(Access {
            time: self.time,
            addr,
            pc: Self::pc(kind, site),
            kind,
            session: s.id,
            ctx_len: s.ctx,
            layer: 0,
            is_write,
        });
    }

    fn maybe_churn(&mut self) {
        if self.rng.chance(self.cfg.churn_p) {
            let slot = self.rng.range_usize(0, self.tenants.len());
            self.tenants[slot] = Self::fresh_tenant(
                &self.cfg,
                &self.footprint_rank,
                &mut self.rng,
                &mut self.next_tenant_id,
            );
            // Sessions of the departed tenant run out naturally; their
            // remaining reads land in the fresh tenant's (cold) footprint,
            // which is exactly the pollution churn causes.
        }
    }

    fn admit_session(&mut self) -> bool {
        if self.sessions.len() >= self.cfg.max_live_sessions {
            return false;
        }
        let slot = self.tenant_select.sample(&mut self.rng) as usize;
        let tenant_id = self.tenants[slot].id;
        self.session_counter = self.session_counter.wrapping_add(1);
        let id = ((tenant_id & 0xFFFF) << 16) | (self.session_counter & 0xFFFF);
        let tokens =
            self.rng.next_exp(1.0 / self.cfg.session_tokens_mean).round().clamp(4.0, 512.0) as u32;
        let s = Sess { id, slot, ctx: 0, tokens_left: tokens };
        // Prefill: scan the shared system-prompt prefix (the cross-tenant
        // reuse surface), then seed the tenant footprint with a few writes.
        let scan = self.cfg.shared_prefix_lines.min(PREFIX_SCAN);
        for i in 0..scan {
            self.push(&s, StreamKind::KvRead, SHARED_PREFIX_BASE + i * LINE, 7, false);
        }
        for _ in 0..4 {
            let t = &mut self.tenants[slot];
            let addr = t.base() + (t.footprint + t.append % APPEND_RING) * LINE;
            t.append += 1;
            self.push(&s, StreamKind::KvWrite, addr, 2, true);
        }
        self.sessions.push(s);
        true
    }

    fn decode_token(&mut self, si: usize) {
        let s = self.sessions[si].clone();
        let embed = region::EMBED + self.embed_zipf.sample(&mut self.rng) * 128;
        self.push(&s, StreamKind::Embedding, embed, 1, false);
        for _ in 0..self.cfg.reads_per_token {
            let addr = if self.rng.chance(self.cfg.prefix_read_p) {
                SHARED_PREFIX_BASE + self.prefix_zipf.sample(&mut self.rng) * LINE
            } else {
                let t = &self.tenants[s.slot];
                t.base() + self.tenants[s.slot].zipf.sample(&mut self.rng) * LINE
            };
            self.push(&s, StreamKind::KvRead, addr, 4, false);
        }
        {
            let t = &mut self.tenants[s.slot];
            let addr = t.base() + (t.footprint + t.append % APPEND_RING) * LINE;
            t.append += 1;
            self.push(&s, StreamKind::KvWrite, addr, 2, true);
        }
        let scratch = region::SCRATCH + (self.scratch_head % (1 << 14)) * LINE;
        self.scratch_head += 1;
        self.push(&s, StreamKind::Scratch, scratch, 5, true);

        self.tokens_done += 1;
        let sess = &mut self.sessions[si];
        sess.ctx += 1;
        sess.tokens_left -= 1;
        if sess.tokens_left == 0 {
            self.sessions.swap_remove(si);
            self.sessions_completed += 1;
        }
    }

    fn refill(&mut self) {
        while self.pending.is_empty() {
            self.maybe_churn();
            if self.rng.chance(self.cfg.arrival_p) {
                self.admit_session();
            }
            if self.sessions.is_empty() {
                // Never starve the stream: population scenarios are
                // closed-loop, a new session replaces the drained mix.
                self.admit_session();
                continue;
            }
            let si = self.rng.range_usize(0, self.sessions.len());
            self.decode_token(si);
        }
    }

    pub fn next_access(&mut self) -> Access {
        self.refill();
        self.pending.pop_front().expect("refill produced accesses")
    }
}

impl Workload for PopulationWorkload {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn next_access(&mut self) -> Access {
        PopulationWorkload::next_access(self)
    }

    fn tokens_done(&self) -> u64 {
        self.tokens_done
    }

    fn sessions_completed(&self) -> u64 {
        self.sessions_completed
    }

    fn live_sessions(&self) -> usize {
        self.sessions.len()
    }

    fn has_work(&self) -> bool {
        !self.pending.is_empty() || !self.sessions.is_empty()
    }

    fn force_arrival(&mut self) -> bool {
        self.admit_session()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    fn workload(seed: u64) -> PopulationWorkload {
        PopulationWorkload::with_name(PopulationConfig::prefix_share(seed), "prefix-share")
    }

    #[test]
    fn stream_is_seed_deterministic_and_monotone() {
        let a = workload(5).generate(20_000);
        let b = workload(5).generate(20_000);
        assert_eq!(a, b);
        let c = workload(6).generate(20_000);
        assert_ne!(a, c);
        assert!(a.windows(2).all(|p| p[0].time < p[1].time), "time must be strictly increasing");
    }

    #[test]
    fn shared_prefix_is_reused_across_tenants() {
        let mut w = workload(9);
        let trace = w.generate(40_000);
        assert!(w.tokens_done() > 0);
        let span = PopulationConfig::prefix_share(9).shared_prefix_lines * LINE;
        let tenants_on_prefix: HashSet<u32> = trace
            .iter()
            .filter(|a| a.addr >= SHARED_PREFIX_BASE && a.addr < SHARED_PREFIX_BASE + span)
            .map(|a| a.session >> 16)
            .collect();
        assert!(
            tenants_on_prefix.len() >= 2,
            "shared prefix must be read by multiple tenants: {tenants_on_prefix:?}"
        );
    }

    #[test]
    fn churn_rotates_tenant_ids() {
        let mut w = workload(3);
        let before: HashSet<u32> = w.tenant_ids().into_iter().collect();
        let _ = w.generate(120_000);
        let after: HashSet<u32> = w.tenant_ids().into_iter().collect();
        assert!(
            after.iter().any(|id| !before.contains(id)),
            "churn must introduce fresh tenants: before={before:?} after={after:?}"
        );
    }

    #[test]
    fn addresses_stay_in_their_regions() {
        let trace = workload(1).generate(30_000);
        for a in &trace {
            let want = match a.kind {
                StreamKind::Embedding => region::of(region::EMBED),
                StreamKind::KvRead | StreamKind::KvWrite => region::of(region::KV),
                StreamKind::Weight => region::of(region::WEIGHT),
                StreamKind::Scratch => region::of(region::SCRATCH),
            };
            assert_eq!(region::of(a.addr), want, "{a:?}");
        }
    }
}
