//! Integration tests over the serving coordinator: multi-worker runs with
//! router policies, batched prediction service, and (artifact-gated) the
//! real TCN behind the service thread.

use acpc::coordinator::{serve, RouterPolicy, ServeConfig};
use acpc::predictor::{HeuristicPredictor, ModelRuntime, PredictorBox};
use acpc::runtime::{artifacts_dir, Engine, Manifest};
use std::time::Duration;

#[test]
fn four_workers_complete_all_sessions() {
    let mut cfg = ServeConfig::quick("srrip");
    cfg.workers = 4;
    cfg.total_sessions = 32;
    cfg.arrival_interval = Duration::from_micros(50);
    let rep = serve(&cfg, 0, || PredictorBox::None);
    assert_eq!(rep.sessions_admitted, 32);
    assert!(rep.sessions_completed >= 31, "completed {}", rep.sessions_completed);
    assert!(rep.tokens > 100);
    assert!(rep.session_latency_ms_p95 >= rep.session_latency_ms_p50);
}

#[test]
fn round_robin_and_least_loaded_both_work() {
    for router in [RouterPolicy::RoundRobin, RouterPolicy::LeastLoaded] {
        let mut cfg = ServeConfig::quick("lru");
        cfg.router = router;
        cfg.total_sessions = 12;
        let rep = serve(&cfg, 0, || PredictorBox::None);
        assert!(rep.sessions_completed >= 11, "{router:?}: {}", rep.sessions_completed);
    }
}

#[test]
fn predictor_service_feeds_acpc_policy() {
    let mut cfg = ServeConfig::quick("acpc");
    cfg.total_sessions = 16;
    cfg.predict_batch = 64;
    let rep = serve(&cfg, 1, || PredictorBox::Heuristic(HeuristicPredictor));
    assert!(rep.prediction_batches > 0);
    assert!(rep.mean_batch_fill >= 1.0);
    assert!(rep.l2_hit_rate > 0.2);
}

#[test]
fn single_worker_degenerate_case() {
    let mut cfg = ServeConfig::quick("acpc");
    cfg.workers = 1;
    cfg.total_sessions = 6;
    let rep = serve(&cfg, 1, || PredictorBox::Heuristic(HeuristicPredictor));
    assert!(rep.sessions_completed >= 5);
    assert_eq!(rep.router_imbalance_max, 0, "one worker → max-min load is always 0");
}

/// Real TCN behind the prediction service — the serving-paper configuration
/// (artifact-gated).
#[test]
fn serve_with_real_tcn_artifact() {
    if artifacts_dir().is_none() {
        eprintln!("SKIP: artifacts/ not built");
        return;
    }
    let manifest = Manifest::load(&artifacts_dir().unwrap()).unwrap();
    let window = manifest.model("tcn").unwrap().window;
    let mut cfg = ServeConfig::quick("acpc");
    cfg.total_sessions = 12;
    cfg.predict_batch = 128;
    cfg.predict_deadline = Duration::from_millis(5);
    let rep = serve(&cfg, window, || {
        let dir = artifacts_dir().unwrap();
        let manifest = Manifest::load(&dir).unwrap();
        let engine = Engine::cpu().unwrap();
        let rt = ModelRuntime::load(&engine, &manifest, "tcn").unwrap();
        PredictorBox::Model(Box::new(rt))
    });
    assert!(rep.sessions_completed >= 11, "completed {}", rep.sessions_completed);
    assert!(rep.prediction_batches > 0, "TCN service must have run");
}
