//! Integration tests for the set-sharded simulator, driven through the
//! public `RunSpec` → `Runner` API (`shards > 1`): exact aggregate
//! invariance across shard counts for set-local configurations,
//! per-shard-count determinism for ML-predictor and adaptive runs, and
//! validation of unshardable inputs.

use acpc::adapt::ControllerConfig;
use acpc::api::{run_compare, AdaptSpec, PredictorFactory, RunReport, RunSpec, Runner};
use acpc::config::PredictorKind;
use acpc::metrics::MetricsReport;
use acpc::predictor::{PredictorBox, FEATURE_DIM};
use acpc::runtime::{synthetic_model, NativeModel, NativeWeights};
use std::sync::Arc;

/// Assert every aggregate metric is bit-identical, *except* EMU: EMU is a
/// time-sampled statistic and the sampling instants are shard-local (every
/// 8192 shard-steps), so it is the one field that is only approximately
/// shard-invariant. All event-counter-derived metrics must match exactly.
fn assert_reports_match(a: &MetricsReport, b: &MetricsReport, ctx: &str) {
    assert_eq!(a.policy, b.policy, "{ctx}: policy");
    assert_eq!(a.accesses, b.accesses, "{ctx}: accesses");
    assert_eq!(a.tokens, b.tokens, "{ctx}: tokens");
    assert_eq!(a.l1_hit_rate.to_bits(), b.l1_hit_rate.to_bits(), "{ctx}: l1_hit_rate");
    assert_eq!(a.l2_hit_rate.to_bits(), b.l2_hit_rate.to_bits(), "{ctx}: l2_hit_rate");
    assert_eq!(a.l3_hit_rate.to_bits(), b.l3_hit_rate.to_bits(), "{ctx}: l3_hit_rate");
    assert_eq!(
        a.l2_pollution_ratio.to_bits(),
        b.l2_pollution_ratio.to_bits(),
        "{ctx}: l2_pollution_ratio"
    );
    assert_eq!(a.l2_dead_prefetch_evictions, b.l2_dead_prefetch_evictions, "{ctx}: dead pf");
    assert_eq!(
        a.l2_demand_evicted_by_prefetch, b.l2_demand_evicted_by_prefetch,
        "{ctx}: evicted-by-pf"
    );
    assert_eq!(a.l2_miss_cycles, b.l2_miss_cycles, "{ctx}: l2_miss_cycles");
    assert_eq!(a.amat.to_bits(), b.amat.to_bits(), "{ctx}: amat");
    assert_eq!(a.prefetches_issued, b.prefetches_issued, "{ctx}: prefetches_issued");
    assert_eq!(a.total_latency, b.total_latency, "{ctx}: total_latency");
}

fn spec_for(
    policy: &str,
    predictor: PredictorKind,
    prefetcher: &str,
    accesses: usize,
) -> acpc::api::RunSpecBuilder {
    RunSpec::builder()
        .scenario("decode-heavy")
        .policy(policy)
        .predictor(predictor)
        .accesses(accesses)
        .seed(0x51AB_D5EE)
        .prefetcher(prefetcher)
}

/// A fully set-local configuration: every level's policy is per-set state
/// only (the default DRRIP LLC carries a global PSEL + RNG and is therefore
/// only deterministic per shard count, not shard-count-invariant).
fn set_local_spec(policy: &str, accesses: usize, shards: usize) -> RunSpec {
    spec_for(policy, PredictorKind::None, "none", accesses)
        .l3_policy("srrip")
        .shards(shards)
        .build()
        .expect("valid set-local spec")
}

fn run(spec: RunSpec) -> RunReport {
    Runner::new(spec).expect("resolve").run().expect("sharded run")
}

/// Classic set-local policies with the prefetcher off: aggregate metrics
/// must be byte-identical for shards ∈ {1, 2, 8} — the set partition is
/// exact, not approximate.
#[test]
fn classic_policies_invariant_across_shard_counts() {
    for policy in ["lru", "srrip"] {
        let reference = run(set_local_spec(policy, 120_000, 1));
        for shards in [2usize, 8] {
            let sharded = run(set_local_spec(policy, 120_000, shards));
            assert_reports_match(
                &sharded.result.report,
                &reference.result.report,
                &format!("{policy} @ {shards} shards"),
            );
            assert_eq!(sharded.result.report.accesses, 120_000, "{policy}");
            assert_eq!(sharded.result.tokens, reference.result.tokens, "{policy}");
        }
    }
}

/// The belady oracle annotates next-use with *global* positions, which
/// stay comparable inside each set — sharded belady must match too.
#[test]
fn belady_oracle_invariant_across_shard_counts() {
    let a = run(set_local_spec("belady", 60_000, 1));
    let b = run(set_local_spec("belady", 60_000, 4));
    assert_reports_match(&a.result.report, &b.result.report, "belady @ 4 shards");
}

/// With the composite prefetcher the history tables become per-shard, so
/// aggregates may shift slightly across shard counts — but a fixed shard
/// count must stay fully deterministic, and every access must be simulated.
#[test]
fn prefetching_runs_deterministic_per_shard_count() {
    let mk = || {
        spec_for("lru", PredictorKind::None, "composite", 80_000)
            .shards(4)
            .build()
            .unwrap()
    };
    let a = run(mk());
    let b = run(mk());
    assert_eq!(
        a.result.report.to_json().to_pretty(),
        b.result.report.to_json().to_pretty()
    );
    assert_eq!(a.result.report.accesses, 80_000);
}

/// ML-policy runs (`acpc` + heuristic predictor): per-shard batching makes
/// shard counts distinct regimes, but each is deterministic, simulates the
/// full stream, and actually exercises the prediction pipeline per shard.
#[test]
fn heuristic_predictor_deterministic_per_shard_count() {
    let mk = || {
        spec_for("acpc", PredictorKind::Heuristic, "composite", 100_000)
            .shards(8)
            .build()
            .unwrap()
    };
    let a = run(mk());
    let b = run(mk());
    assert_eq!(
        a.result.report.to_json().to_pretty(),
        b.result.report.to_json().to_pretty()
    );
    assert_eq!(a.result.prediction_batches, b.result.prediction_batches);
    assert!(a.result.prediction_batches > 0, "predictor must have run in the shards");
    assert_eq!(a.result.report.accesses, 100_000);
}

/// Native-kernel predictors: every shard predicts over a clone of *one*
/// shared weight snapshot (the `Send` property the per-thread PJRT cache
/// could never offer). Each shard count must be deterministic across
/// reruns, and the prediction pipeline must actually run in the shards.
#[test]
fn native_predictor_shares_one_snapshot_across_shards() {
    let (mm, store) = synthetic_model("tcn", 16, FEATURE_DIM, 16, &[1, 2, 4], 0x5EED);
    let weights = Arc::new(NativeWeights::from_params(&mm, &store).unwrap());
    let run_with = |shards: usize| {
        let w = Arc::clone(&weights);
        let factory: PredictorFactory =
            Arc::new(move |_shard| PredictorBox::Native(NativeModel::from_weights(Arc::clone(&w))));
        let spec = spec_for("acpc", PredictorKind::Tcn, "composite", 100_000)
            .shards(shards)
            .build()
            .unwrap();
        Runner::new(spec).unwrap().with_predictor_factory(factory).run().unwrap()
    };
    for shards in [1usize, 8] {
        let a = run_with(shards);
        let b = run_with(shards);
        assert_eq!(
            a.result.report.to_json().to_pretty(),
            b.result.report.to_json().to_pretty(),
            "native predictor must be deterministic at {shards} shard(s)"
        );
        assert!(a.result.prediction_batches > 0, "predictions must run at {shards} shard(s)");
        assert_eq!(a.result.report.accesses, 100_000);
        assert_eq!(a.predictor_effective, "tcn");
    }
}

/// Sharded adaptive runs: one controller per shard, drift detection and
/// event logs deterministic for a fixed shard count; the merged summary
/// carries the per-shard telemetry.
#[test]
fn sharded_adaptive_drift_is_deterministic() {
    let spec = RunSpec::builder()
        .scenario("multi-tenant-mix")
        .policy("acpc")
        .predictor(PredictorKind::Heuristic)
        .accesses(120_000)
        .seed(0xD51F7)
        .shards(4)
        .adaptive_spec(AdaptSpec {
            window_accesses: Some(2048),
            ..AdaptSpec::from_config(&ControllerConfig::quick())
        })
        .build()
        .unwrap();
    let a = run_compare(&spec).unwrap();
    let b = run_compare(&spec).unwrap();
    assert_eq!(a.summary.drift_windows, b.summary.drift_windows);
    assert_eq!(a.summary.swaps, b.summary.swaps);
    assert_eq!(a.summary.throttled_windows, b.summary.throttled_windows);
    assert_eq!(a.summary.events.len(), b.summary.events.len());
    assert_eq!(
        a.adaptive.report.to_json().to_pretty(),
        b.adaptive.report.to_json().to_pretty()
    );
    assert!(a.summary.windows_observed > 0, "per-shard controllers must tick windows");
    // Both arms simulated the full stream.
    assert_eq!(a.baseline.report.accesses, 120_000);
    assert_eq!(a.adaptive.report.accesses, 120_000);
}

/// Unshardable inputs are rejected at spec resolution, not deep in a
/// worker thread.
#[test]
fn invalid_shard_counts_rejected() {
    assert!(
        spec_for("lru", PredictorKind::None, "none", 10_000).shards(3).build().is_err(),
        "non-power-of-two shard count"
    );
    assert!(
        spec_for("lru", PredictorKind::None, "none", 10_000).shards(64).build().is_err(),
        "more shards than the smallest level's set count"
    );
}
