//! Integration tests for the set-sharded simulator: exact aggregate
//! invariance across shard counts for set-local configurations,
//! per-shard-count determinism for ML-predictor and adaptive runs, and
//! validation of unshardable inputs.

use acpc::adapt::{run_compare_sharded, ControllerConfig};
use acpc::config::{ExperimentConfig, PredictorKind};
use acpc::metrics::MetricsReport;
use acpc::predictor::{HeuristicPredictor, PredictorBox};
use acpc::sim::{run_workload_sharded, ShardedRun};

/// Assert every aggregate metric is bit-identical, *except* EMU: EMU is a
/// time-sampled statistic and the sampling instants are shard-local (every
/// 8192 shard-steps), so it is the one field that is only approximately
/// shard-invariant. All event-counter-derived metrics must match exactly.
fn assert_reports_match(a: &MetricsReport, b: &MetricsReport, ctx: &str) {
    assert_eq!(a.policy, b.policy, "{ctx}: policy");
    assert_eq!(a.accesses, b.accesses, "{ctx}: accesses");
    assert_eq!(a.tokens, b.tokens, "{ctx}: tokens");
    assert_eq!(a.l1_hit_rate.to_bits(), b.l1_hit_rate.to_bits(), "{ctx}: l1_hit_rate");
    assert_eq!(a.l2_hit_rate.to_bits(), b.l2_hit_rate.to_bits(), "{ctx}: l2_hit_rate");
    assert_eq!(a.l3_hit_rate.to_bits(), b.l3_hit_rate.to_bits(), "{ctx}: l3_hit_rate");
    assert_eq!(
        a.l2_pollution_ratio.to_bits(),
        b.l2_pollution_ratio.to_bits(),
        "{ctx}: l2_pollution_ratio"
    );
    assert_eq!(a.l2_dead_prefetch_evictions, b.l2_dead_prefetch_evictions, "{ctx}: dead pf");
    assert_eq!(
        a.l2_demand_evicted_by_prefetch, b.l2_demand_evicted_by_prefetch,
        "{ctx}: evicted-by-pf"
    );
    assert_eq!(a.l2_miss_cycles, b.l2_miss_cycles, "{ctx}: l2_miss_cycles");
    assert_eq!(a.amat.to_bits(), b.amat.to_bits(), "{ctx}: amat");
    assert_eq!(a.prefetches_issued, b.prefetches_issued, "{ctx}: prefetches_issued");
    assert_eq!(a.total_latency, b.total_latency, "{ctx}: total_latency");
}

fn cfg_for(
    policy: &str,
    predictor: PredictorKind,
    prefetcher: &str,
    accesses: usize,
) -> ExperimentConfig {
    let mut cfg =
        ExperimentConfig::for_scenario("decode-heavy", policy, predictor, 0x51AB_D5EE).unwrap();
    cfg.accesses = accesses;
    cfg.hierarchy.prefetcher = prefetcher.into();
    cfg
}

/// A fully set-local configuration: every level's policy is per-set state
/// only (the default DRRIP LLC carries a global PSEL + RNG and is therefore
/// only deterministic per shard count, not shard-count-invariant).
fn set_local_cfg(policy: &str, accesses: usize) -> ExperimentConfig {
    let mut cfg = cfg_for(policy, PredictorKind::None, "none", accesses);
    cfg.hierarchy.l3_policy = "srrip".into();
    cfg
}

fn run_sharded(cfg: &ExperimentConfig, shards: usize, kind: PredictorKind) -> ShardedRun {
    let mk = move |_s: usize| -> PredictorBox {
        match kind {
            PredictorKind::Heuristic => PredictorBox::Heuristic(HeuristicPredictor),
            _ => PredictorBox::None,
        }
    };
    let mut w = cfg.workload();
    run_workload_sharded(cfg, w.as_mut(), shards, &mk, None).expect("sharded run")
}

/// Classic set-local policies with the prefetcher off: aggregate metrics
/// must be byte-identical for shards ∈ {1, 2, 8} — the set partition is
/// exact, not approximate.
#[test]
fn classic_policies_invariant_across_shard_counts() {
    for policy in ["lru", "srrip"] {
        let cfg = set_local_cfg(policy, 120_000);
        let reference = run_sharded(&cfg, 1, PredictorKind::None);
        for shards in [2usize, 8] {
            let run = run_sharded(&cfg, shards, PredictorKind::None);
            assert_reports_match(
                &run.result.report,
                &reference.result.report,
                &format!("{policy} @ {shards} shards"),
            );
            assert_eq!(run.result.report.accesses, 120_000, "{policy}");
            assert_eq!(run.result.tokens, reference.result.tokens, "{policy}");
        }
    }
}

/// The belady oracle annotates next-use with *global* positions, which
/// stay comparable inside each set — sharded belady must match too.
#[test]
fn belady_oracle_invariant_across_shard_counts() {
    let cfg = set_local_cfg("belady", 60_000);
    let a = run_sharded(&cfg, 1, PredictorKind::None);
    let b = run_sharded(&cfg, 4, PredictorKind::None);
    assert_reports_match(&a.result.report, &b.result.report, "belady @ 4 shards");
}

/// With the composite prefetcher the history tables become per-shard, so
/// aggregates may shift slightly across shard counts — but a fixed shard
/// count must stay fully deterministic, and every access must be simulated.
#[test]
fn prefetching_runs_deterministic_per_shard_count() {
    let cfg = cfg_for("lru", PredictorKind::None, "composite", 80_000);
    let a = run_sharded(&cfg, 4, PredictorKind::None);
    let b = run_sharded(&cfg, 4, PredictorKind::None);
    assert_eq!(
        a.result.report.to_json().to_pretty(),
        b.result.report.to_json().to_pretty()
    );
    assert_eq!(a.result.report.accesses, 80_000);
}

/// ML-policy runs (`acpc` + heuristic predictor): per-shard batching makes
/// shard counts distinct regimes, but each is deterministic, simulates the
/// full stream, and actually exercises the prediction pipeline per shard.
#[test]
fn heuristic_predictor_deterministic_per_shard_count() {
    let cfg = cfg_for("acpc", PredictorKind::Heuristic, "composite", 100_000);
    let a = run_sharded(&cfg, 8, PredictorKind::Heuristic);
    let b = run_sharded(&cfg, 8, PredictorKind::Heuristic);
    assert_eq!(
        a.result.report.to_json().to_pretty(),
        b.result.report.to_json().to_pretty()
    );
    assert_eq!(a.result.prediction_batches, b.result.prediction_batches);
    assert!(a.result.prediction_batches > 0, "predictor must have run in the shards");
    assert_eq!(a.result.report.accesses, 100_000);
}

/// Sharded adaptive runs: one controller per shard, drift detection and
/// event logs deterministic for a fixed shard count; the merged summary
/// carries the per-shard telemetry.
#[test]
fn sharded_adaptive_drift_is_deterministic() {
    let mut cfg = ExperimentConfig::for_scenario(
        "multi-tenant-mix",
        "acpc",
        PredictorKind::Heuristic,
        0xD51F7,
    )
    .unwrap();
    cfg.accesses = 120_000;
    let mut ccfg = ControllerConfig::quick();
    ccfg.window_accesses = 2048;
    let mk = |_s: usize| PredictorBox::Heuristic(HeuristicPredictor);
    let a = run_compare_sharded(&cfg, &ccfg, 4, &mk).unwrap();
    let b = run_compare_sharded(&cfg, &ccfg, 4, &mk).unwrap();
    assert_eq!(a.summary.drift_windows, b.summary.drift_windows);
    assert_eq!(a.summary.swaps, b.summary.swaps);
    assert_eq!(a.summary.throttled_windows, b.summary.throttled_windows);
    assert_eq!(a.summary.events.len(), b.summary.events.len());
    assert_eq!(
        a.adaptive.report.to_json().to_pretty(),
        b.adaptive.report.to_json().to_pretty()
    );
    assert!(a.summary.windows_observed > 0, "per-shard controllers must tick windows");
    // Both arms simulated the full stream.
    assert_eq!(a.baseline.report.accesses, 120_000);
    assert_eq!(a.adaptive.report.accesses, 120_000);
}

/// Unshardable inputs are rejected up front, not deep in a worker thread.
#[test]
fn invalid_shard_counts_rejected() {
    let cfg = cfg_for("lru", PredictorKind::None, "none", 10_000);
    let mk = |_s: usize| PredictorBox::None;
    let mut w = cfg.workload();
    assert!(
        run_workload_sharded(&cfg, w.as_mut(), 3, &mk, None).is_err(),
        "non-power-of-two shard count"
    );
    let mut w = cfg.workload();
    assert!(
        run_workload_sharded(&cfg, w.as_mut(), 64, &mk, None).is_err(),
        "more shards than the smallest level's set count"
    );
}
