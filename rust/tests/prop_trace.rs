//! Property-based tests over the trace generator, labeler, feature
//! extractor and dataset pipeline: structural invariants for any seed/knob
//! combination.

use acpc::predictor::{labeler, Dataset, FeatureExtractor, GeometryHints, FEATURE_DIM};
use acpc::trace::file::{read_trace, write_trace, write_trace_v2, TraceReader, TraceRecord};
use acpc::trace::{region, Access, GeneratorConfig, ModelProfile, StreamKind, TraceGenerator};
use acpc::util::proptest::prop_check;

fn random_config(g: &mut acpc::util::proptest::Gen) -> GeneratorConfig {
    let profile = match g.usize(0, 2) {
        0 => ModelProfile::gpt3ish(),
        1 => ModelProfile::llama2ish(),
        _ => ModelProfile::t5ish(),
    };
    let mut cfg = GeneratorConfig::new(profile, g.u64(0, 1 << 40));
    cfg.max_live_sessions = g.usize(1, 12);
    cfg.max_ctx = *g.pick(&[64u32, 128, 256]) as u32;
    cfg.phase_period = *g.pick(&[0u64, 1000, 50_000]);
    cfg.profile.layers = g.usize(1, 12) as u16;
    cfg
}

/// Generator invariants: strictly increasing time, valid regions, ctx_len
/// within bounds, KV addresses inside their slot, deterministic per seed.
#[test]
fn prop_generator_invariants() {
    prop_check("generator invariants", 25, |g| {
        let cfg = random_config(g);
        let n = g.usize(2_000, 20_000);
        let kv_layer_bytes = cfg.max_ctx as u64 * cfg.profile.kv_bytes_per_token;
        let kv_slot_bytes = kv_layer_bytes * cfg.profile.layers as u64;
        let kv_total = kv_slot_bytes * cfg.max_live_sessions as u64;
        let trace = TraceGenerator::new(cfg.clone()).generate(n);
        let trace2 = TraceGenerator::new(cfg.clone()).generate(n);
        if trace != trace2 {
            return Err("non-deterministic for identical config".into());
        }
        let mut last_t = 0;
        for a in &trace {
            if a.time <= last_t {
                return Err(format!("time not strictly increasing at {}", a.time));
            }
            last_t = a.time;
            if a.ctx_len >= cfg.max_ctx {
                return Err(format!("ctx_len {} >= max_ctx {}", a.ctx_len, cfg.max_ctx));
            }
            match a.kind {
                StreamKind::KvRead | StreamKind::KvWrite => {
                    let off = a.addr - region::KV;
                    if off >= kv_total {
                        return Err(format!("KV address outside slot space: {off} >= {kv_total}"));
                    }
                    if a.kind == StreamKind::KvWrite && !a.is_write {
                        return Err("KvWrite not marked as write".into());
                    }
                }
                StreamKind::Embedding => {
                    let off = a.addr - region::EMBED;
                    let max = cfg.profile.vocab * cfg.profile.embed_row_bytes;
                    if off >= max {
                        return Err(format!("embedding address beyond table: {off}"));
                    }
                }
                _ => {}
            }
        }
        Ok(())
    });
}

fn random_access(g: &mut acpc::util::proptest::Gen, time: u64) -> Access {
    Access {
        time,
        addr: g.u64(0, 1 << 44),
        pc: g.u64(0, 1 << 20),
        kind: StreamKind::from_u8(g.usize(0, 4) as u8),
        session: g.u64(0, 1 << 16) as u32,
        ctx_len: g.u64(0, 4096) as u32,
        layer: g.u64(0, 96) as u16,
        is_write: g.bool(),
    }
}

/// `.acpctrace` round-trip: any record stream survives v1 (accesses only)
/// and v2 (tenant + arrival + header totals) write/read bit-for-bit, the
/// streaming [`TraceReader`] agrees with the bulk wrappers, and v1 files
/// read back with zeroed provenance.
#[test]
fn prop_trace_file_roundtrip_v1_v2() {
    let dir = std::env::temp_dir().join("acpc_prop_trace_file");
    std::fs::create_dir_all(&dir).unwrap();
    let case_counter = std::cell::Cell::new(0usize);
    prop_check("trace file round-trip", 12, |g| {
        let case = case_counter.get() + 1;
        case_counter.set(case);
        let n = g.usize(1, 400);
        let mut time = 0u64;
        let records: Vec<TraceRecord> = (0..n)
            .map(|_| {
                time += g.u64(1, 50);
                TraceRecord {
                    access: random_access(g, time),
                    tenant: g.u64(0, 64) as u32,
                    arrival: g.u64(0, 1 << 30),
                }
            })
            .collect();
        let accesses: Vec<Access> = records.iter().map(|r| r.access).collect();

        // v1: accesses only.
        let v1 = dir.join(format!("case{case}.v1.acpctrace"));
        write_trace(&v1, &accesses).map_err(|e| e.to_string())?;
        if read_trace(&v1).map_err(|e| e.to_string())? != accesses {
            return Err("v1 bulk read mismatch".into());
        }
        let rd = TraceReader::open(&v1).map_err(|e| e.to_string())?;
        if rd.version() != 1 || rd.count() != n as u64 {
            return Err(format!("v1 header: version {} count {}", rd.version(), rd.count()));
        }
        for (i, r) in rd.enumerate() {
            let r = r.map_err(|e| e.to_string())?;
            if r.access != accesses[i] || r.tenant != 0 || r.arrival != 0 {
                return Err(format!("v1 streaming record {i} mismatch"));
            }
        }

        // v2: provenance-preserving.
        let tokens = g.u64(0, 1 << 30);
        let sessions = g.u64(0, 1 << 20);
        let v2 = dir.join(format!("case{case}.v2.acpctrace"));
        write_trace_v2(&v2, &records, tokens, sessions).map_err(|e| e.to_string())?;
        let rd = TraceReader::open(&v2).map_err(|e| e.to_string())?;
        if rd.version() != 2 || rd.count() != n as u64 {
            return Err(format!("v2 header: version {} count {}", rd.version(), rd.count()));
        }
        if rd.tokens() != tokens || rd.sessions() != sessions {
            return Err("v2 header totals mismatch".into());
        }
        let back: Vec<TraceRecord> =
            rd.collect::<Result<_, _>>().map_err(|e| e.to_string())?;
        if back != records {
            return Err("v2 streaming read mismatch".into());
        }
        if read_trace(&v2).map_err(|e| e.to_string())? != accesses {
            return Err("v2 thin-wrapper read mismatch".into());
        }
        Ok(())
    });
    let _ = std::fs::remove_dir_all(&dir);
}

/// Labeler invariants: labels consistent with next_use, and next_use always
/// points forward to the same line.
#[test]
fn prop_labeler_consistency() {
    prop_check("labeler consistency", 20, |g| {
        let cfg = random_config(g);
        let horizon = g.usize(16, 4096);
        let trace = TraceGenerator::new(cfg).generate(g.usize(1_000, 10_000));
        let ann = labeler::annotate(&trace, horizon);
        for (i, a) in ann.iter().enumerate() {
            match a.next_use {
                Some(j) => {
                    let j = j as usize;
                    if j <= i {
                        return Err(format!("next_use {j} <= {i}"));
                    }
                    if trace[j].line() != trace[i].line() {
                        return Err("next_use crosses lines".into());
                    }
                    let within = j - i <= horizon;
                    if a.label != within {
                        return Err(format!("label {} but gap {} horizon {horizon}", a.label, j - i));
                    }
                }
                None => {
                    if a.label {
                        return Err("label true without next use".into());
                    }
                }
            }
        }
        Ok(())
    });
}

/// Feature extractor: all outputs bounded, window sequences chronological
/// (last row equals features_of the current access modulo the pre-update
/// state), and bounded memory.
#[test]
fn prop_feature_extractor_bounded() {
    prop_check("feature extractor bounded", 15, |g| {
        let cfg = random_config(g);
        let geom = GeometryHints::from_generator(&cfg);
        let window = g.usize(2, 16);
        let mut fx = FeatureExtractor::new(window, geom);
        let mut out = vec![0.0f32; window * FEATURE_DIM];
        let mut gen = TraceGenerator::new(cfg);
        for _ in 0..g.usize(2_000, 15_000) {
            let a = gen.next_access();
            fx.push(&a, &mut out);
            for (k, &v) in out.iter().enumerate() {
                if !(0.0..=2.5).contains(&v) || !v.is_finite() {
                    return Err(format!("feature {} out of bounds: {v}", k % FEATURE_DIM));
                }
            }
        }
        Ok(())
    });
}

/// Dataset pipeline: split fractions, disjointness, x/x_cur coherence for
/// any window and sampling stride.
#[test]
fn prop_dataset_split_partition() {
    prop_check("dataset split partition", 10, |g| {
        let cfg = random_config(g);
        let geom = GeometryHints::from_generator(&cfg);
        let window = g.usize(2, 16);
        let stride = g.usize(1, 8);
        let trace = TraceGenerator::new(cfg).generate(20_000);
        let ds = Dataset::build(&trace, window, geom, 1024, stride);
        if ds.n == 0 {
            return Err("empty dataset".into());
        }
        let split = ds.split(g.u64(0, 1 << 30));
        let total = split.train.len() + split.val.len() + split.test.len();
        if total != ds.n {
            return Err(format!("split loses samples: {total} != {}", ds.n));
        }
        let mut seen = vec![false; ds.n];
        for &i in split.train.iter().chain(&split.val).chain(&split.test) {
            if seen[i] {
                return Err(format!("index {i} appears twice"));
            }
            seen[i] = true;
        }
        let frac = split.train.len() as f64 / ds.n as f64;
        if (frac - 0.7).abs() > 0.02 {
            return Err(format!("train fraction {frac}"));
        }
        // x_cur is the last row of x.
        let row = window * FEATURE_DIM;
        for i in (0..ds.n).step_by((ds.n / 13).max(1)) {
            let last = &ds.x[i * row + (window - 1) * FEATURE_DIM..(i + 1) * row];
            let cur = &ds.x_cur[i * FEATURE_DIM..(i + 1) * FEATURE_DIM];
            if last != cur {
                return Err(format!("x_cur mismatch at {i}"));
            }
        }
        Ok(())
    });
}
