//! Allocation audit for the telemetry publish path.
//!
//! Publishing onto the [`acpc::obs::TelemetryBus`] sits on the simulator's
//! per-access hot path (window boundaries and periodic samples), so it must
//! never touch the heap: the ring is sized at construction, events are
//! fixed-size `Copy` values written in place, and serialization happens
//! only subscriber-side. This test drives 50k publishes across every
//! payload variant — with live subscribers attached and the ring wrapping
//! many times — and requires exactly zero allocations.
//!
//! This file intentionally contains a single `#[test]`: the counting
//! allocator is process-global, and a sibling test running concurrently
//! would pollute the count (same discipline as `alloc_predict.rs`).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

use acpc::adapt::{AdaptationAction, AdaptationEvent, WindowStats};
use acpc::obs::{Payload, SourceId, TelemetryBus};

#[test]
fn telemetry_publish_path_does_not_allocate() {
    let bus = TelemetryBus::with_capacity(1024);
    // A subscriber is attached but deliberately never drained: a slow (or
    // absent) reader must cost the publisher nothing.
    let _lagging = bus.subscribe();
    let mut publisher = bus.publisher(SourceId::sim(0));

    let stats = WindowStats {
        index: 7,
        accesses: 8192,
        l2_demand: 4000,
        hit_rate: 0.71,
        pollution: 0.08,
        prefetch_accuracy: 0.55,
        reuse_p50_log2: 9,
    };
    let event = AdaptationEvent {
        window: 7,
        access: 57_344,
        action: AdaptationAction::Throttle,
        hit_rate: 0.41,
        predictor_version: 3,
    };
    let payloads = [
        Payload::Window { stats, throttled: false },
        Payload::Drift { window: 7 },
        Payload::Adaptation(event),
        Payload::Sample { occupancy: 0.93, hit_rate: 0.7, pollution: 0.1, throttled: false },
    ];

    // Warmup (the ring itself was sized in `with_capacity`, but let any
    // lazy one-time machinery run once).
    for (i, p) in payloads.iter().enumerate() {
        publisher.publish(i as u64, *p);
    }

    let before = ALLOCS.load(Ordering::SeqCst);
    for i in 0..50_000u64 {
        publisher.publish(i, payloads[(i % 4) as usize]);
    }
    let delta = ALLOCS.load(Ordering::SeqCst) - before;
    assert_eq!(
        delta, 0,
        "telemetry publish performed {delta} heap allocations over 50k events \
         (expected 0: publish must be a fixed-size in-place ring write)"
    );
    assert_eq!(bus.published(), 50_004);
}
