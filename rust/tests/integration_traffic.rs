//! Integration tests over the population-scale traffic subsystem: open-loop
//! arrival determinism and shard invariance, serve-trace capture → replay
//! bit-for-bit fidelity, and the multi-tenant population scenario.

use acpc::api::{RunReport, RunSpec, Runner};
use acpc::config::PredictorKind;
use acpc::coordinator::{serve, ServeConfig};
use acpc::predictor::PredictorBox;
use acpc::trace::file::TraceReader;
use acpc::trace::{Scenario, Workload};
use acpc::traffic::{ReplayWorkload, SHARED_PREFIX_BASE};
use std::path::PathBuf;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("acpc_integration_traffic");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn open_loop_report(shards: usize) -> RunReport {
    let mut spec = RunSpec::builder()
        .scenario("bursty-batch")
        .policy("srrip")
        .predictor(PredictorKind::None)
        .accesses(60_000)
        .seed(0x7AFF)
        .build()
        .unwrap();
    spec.shards = shards;
    Runner::new(spec).unwrap().run().unwrap()
}

/// The ISSUE's acceptance gate: for a fixed seed, open-loop traffic counters
/// are a pure function of the spec — invariant across `--shards` (the
/// arrival process always runs producer-side on one thread) and across
/// repeated runs.
#[test]
fn open_loop_traffic_is_shard_invariant_and_deterministic() {
    let base = open_loop_report(1);
    let t1 = base.result.traffic.expect("open-loop run must report traffic");
    assert!(t1.offered > 0, "no arrivals offered");
    assert!(t1.admitted > 0, "no arrivals admitted");
    assert!(
        t1.offered >= t1.admitted + t1.shed,
        "offered {} < admitted {} + shed {}",
        t1.offered,
        t1.admitted,
        t1.shed
    );

    for shards in [2usize, 4] {
        let rep = open_loop_report(shards);
        assert_eq!(
            rep.result.traffic,
            Some(t1),
            "traffic counters changed under {shards} shards"
        );
    }

    // Re-running the identical spec reproduces the traffic block *and* the
    // cache metrics byte-for-byte (wall-clock fields live outside both).
    let again = open_loop_report(1);
    assert_eq!(again.result.traffic, Some(t1));
    assert_eq!(
        again.result.report.to_json().to_pretty(),
        base.result.report.to_json().to_pretty(),
        "open-loop metrics are not deterministic"
    );
}

/// Capture a real serve run, then replay it: the replayed access stream
/// must equal the captured one record-for-record, and replay runs must be
/// metric-deterministic.
#[test]
fn serve_capture_replays_bit_for_bit() {
    let path = tmp("serve-capture.acpctrace");
    let mut cfg = ServeConfig::quick("srrip");
    cfg.total_sessions = 12;
    cfg.capture = Some(path.clone());
    let rep = serve(&cfg, 0, || PredictorBox::None);
    assert!(rep.tokens > 0);

    let reader = TraceReader::open(&path).unwrap();
    assert_eq!(reader.version(), 2, "serve captures are v2");
    let count = reader.count() as usize;
    assert!(count > 0, "capture is empty");
    assert_eq!(reader.tokens(), rep.tokens, "header token total");
    let records: Vec<_> = reader.map(|r| r.unwrap()).collect();

    // Tenant ids are worker indices; quick() runs 2 workers and both serve.
    let tenants: std::collections::BTreeSet<u32> =
        records.iter().map(|r| r.tenant).collect();
    assert!(tenants.len() >= 2, "expected multiple capture tenants, got {tenants:?}");

    // The streaming replay workload reproduces the capture exactly.
    let mut replay = ReplayWorkload::open(&path).unwrap();
    let replayed = replay.generate(count);
    let captured: Vec<_> = records.iter().map(|r| r.access).collect();
    assert_eq!(replayed, captured, "replay diverged from capture");

    // And a full Runner replay run is deterministic end to end.
    let spec = RunSpec::builder()
        .policy("lru")
        .predictor(PredictorKind::None)
        .replay(path.to_str().unwrap())
        .build()
        .unwrap();
    let r1 = Runner::new(spec.clone()).unwrap().run().unwrap();
    let r2 = Runner::new(spec).unwrap().run().unwrap();
    assert_eq!(r1.result.report.accesses, count as u64, "replay run length");
    assert_eq!(
        r1.result.report.to_json().to_pretty(),
        r2.result.report.to_json().to_pretty(),
        "replay runs are not deterministic"
    );
}

/// Traffic-backed scenario workloads are pure functions of their seed, like
/// every generator scenario.
#[test]
fn traffic_scenarios_are_seed_deterministic() {
    for name in ["prefix-share", "bursty-batch"] {
        let sc = Scenario::by_name(name).unwrap();
        let a = sc.workload(77).generate(30_000);
        let b = sc.workload(77).generate(30_000);
        assert_eq!(a, b, "{name}: same seed diverged");
        let c = sc.workload(78).generate(30_000);
        assert_ne!(a, c, "{name}: seed is ignored");
    }
}

/// The population scenario's point: distinct tenants hit the *same* shared
/// system-prompt prefix lines (cross-tenant reuse a per-tenant Zipf model
/// cannot produce).
#[test]
fn prefix_share_tenants_reuse_the_shared_prefix() {
    let trace = Scenario::by_name("prefix-share").unwrap().workload(5).generate(60_000);
    // PopulationConfig::prefix_share keeps a 384-line shared prefix block.
    let prefix_end = SHARED_PREFIX_BASE + 384 * 64;
    let tenants: std::collections::BTreeSet<u32> = trace
        .iter()
        .filter(|a| a.addr >= SHARED_PREFIX_BASE && a.addr < prefix_end && !a.is_write)
        .map(|a| a.session >> 16)
        .collect();
    assert!(
        tenants.len() >= 2,
        "shared prefix touched by {} tenant(s), want cross-tenant reuse",
        tenants.len()
    );
}
