//! Integration tests over the tenant-aware serving core: ServeSpec
//! round-trip and validation, session-router determinism, engine counter
//! reconciliation and seed determinism, the noisy-neighbor arbitration
//! story, and tenant-stamped trace capture.

use acpc::serve::{run, ArbiterSpec, ServeSpec, SessionRouter, TenantSpec};
use acpc::trace::file::TraceReader;
use acpc::util::json::Json;
use std::path::PathBuf;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("acpc_integration_serve");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// Two tenants with opposite traffic shapes sharing one worker's cache.
fn contended(ticks: u64, arbitrate: bool) -> ServeSpec {
    ServeSpec::builder()
        .workers(1)
        .ticks(ticks)
        .seed(0xC0FFEE)
        .l2_kb(64)
        .tenant(TenantSpec {
            arrivals: Some("bursty".into()),
            rate: Some(150.0),
            burst_factor: Some(6.0),
            burst_switch_p: Some(0.005),
            ..TenantSpec::new("noisy")
        })
        .tenant(TenantSpec {
            rate: Some(4.0),
            ..TenantSpec::new("quiet")
        })
        .arbiter(ArbiterSpec {
            enabled: Some(arbitrate),
            window_ticks: Some(1000),
            score_threshold: Some(0.01),
            min_share: Some(0.4),
            min_accesses: Some(256),
            warmup_windows: Some(2),
        })
        .build()
        .unwrap()
}

#[test]
fn serve_spec_roundtrips_through_json_files() {
    let spec = ServeSpec::builder()
        .name("rt")
        .policy("srrip")
        .workers(3)
        .ticks(9_000)
        .seed(0xFFFF_FFFF_FFFF_FF17) // > 2^53: must survive JSON as a string
        .vnodes(8)
        .tenant(TenantSpec {
            arrivals: Some("diurnal".into()),
            rate: Some(6.0),
            period: Some(4_000),
            amplitude: Some(0.5),
            bucket_rate: Some(0.01),
            bucket_burst: Some(2.0),
            ..TenantSpec::new("a")
        })
        .tenant(TenantSpec { pin_worker: Some(2), ..TenantSpec::new("b") })
        .build()
        .unwrap();

    let path = tmp("roundtrip.json");
    std::fs::write(&path, spec.to_json().to_pretty()).unwrap();
    let back = ServeSpec::from_file(&path).unwrap();
    assert_eq!(spec, back, "file round-trip must be lossless");
    assert_eq!(back.seed, Some(0xFFFF_FFFF_FFFF_FF17));

    // The resolved copy (what reports embed) round-trips and re-resolves.
    let r = spec.resolve().unwrap();
    let back = ServeSpec::from_json(&r.spec.to_json()).unwrap();
    assert_eq!(r.spec, back);
    assert!(back.resolve().is_ok());
}

#[test]
fn serve_spec_builder_rejects_bad_configurations() {
    let base = || {
        ServeSpec::builder()
            .tenant(TenantSpec::new("a"))
            .tenant(TenantSpec::new("b"))
    };
    assert!(base().build().is_ok());
    assert!(ServeSpec::builder().build().is_err(), "no tenants");
    assert!(base().policy("no-such-policy").build().is_err());
    assert!(base().tenant(TenantSpec::new("a")).build().is_err(), "dup name");
    assert!(base().workers(0).build().is_err());
    assert!(base().window_ticks(0).build().is_err());
    assert!(
        base().scenario("bursty-batch").build().is_err(),
        "traffic scenarios cannot stack under tenant arrivals"
    );
    assert!(
        ServeSpec::builder()
            .tenant(TenantSpec { bucket_burst: Some(4.0), ..TenantSpec::new("a") })
            .build()
            .is_err(),
        "bucket_burst without bucket_rate"
    );
    assert!(
        ServeSpec::builder()
            .workers(2)
            .tenant(TenantSpec { pin_worker: Some(2), ..TenantSpec::new("a") })
            .build()
            .is_err(),
        "pin out of range"
    );
    // Unknown keys are parse errors, not silent drops.
    let j = Json::parse(r#"{"tennants": [{"name": "a"}]}"#).unwrap();
    assert!(ServeSpec::from_json(&j).is_err());
}

#[test]
fn session_router_is_deterministic_and_honors_pins() {
    let all = |_: usize| true;
    let a = SessionRouter::new(8, 16, 0xABCD, vec![None, Some(5)]);
    let b = SessionRouter::new(8, 16, 0xABCD, vec![None, Some(5)]);
    for key in 0..500u64 {
        assert_eq!(a.route(0, key, &all), b.route(0, key, &all), "key {key}");
        assert_eq!(a.route(1, key, &all), Some(5), "pins are absolute");
    }
    // Pins never fail over; unpinned sessions walk past full workers.
    assert_eq!(a.route(1, 0, &|w| w != 5), None);
    let home = a.route(0, 7, &all).unwrap();
    let next = a.route(0, 7, &|w| w != home).unwrap();
    assert_ne!(next, home);
}

#[test]
fn engine_reruns_reproduce_per_tenant_counters_and_embed_the_spec() {
    let spec = contended(4_000, true);
    let a = run(&spec).unwrap();
    let b = run(&spec).unwrap();
    assert_eq!(a.tenants.len(), 2);
    for (x, y) in a.tenants.iter().zip(b.tenants.iter()) {
        // The audited admission identity: every offered session has exactly
        // one terminal disposition.
        assert_eq!(x.offered, x.admitted + x.shed + x.deferred, "{}", x.name);
        assert_eq!(
            (x.offered, x.admitted, x.shed, x.deferred, x.accesses, x.tokens),
            (y.offered, y.admitted, y.shed, y.deferred, y.accesses, y.tokens),
            "{} not deterministic across reruns",
            x.name
        );
    }

    // The report embeds the fully-resolved spec; running *that* reproduces
    // the run — a report is a recipe.
    let j = a.to_json();
    let embedded = j.get("serve_spec").expect("report embeds its resolved spec");
    let back = ServeSpec::from_json(embedded).unwrap();
    let c = run(&back).unwrap();
    for (x, z) in a.tenants.iter().zip(c.tenants.iter()) {
        assert_eq!(
            (x.offered, x.admitted, x.shed, x.deferred, x.accesses),
            (z.offered, z.admitted, z.shed, z.deferred, z.accesses),
            "{}: embedded spec did not reproduce the run",
            x.name
        );
    }
}

/// The tentpole claim: with a bursty tenant thrashing a small shared L2,
/// turning the arbiter on (same seed, same arrivals) leaves the steady
/// tenant strictly better off — higher hit rate, no more pollution — by
/// throttling the noisy tenant's admissions.
#[test]
fn arbitration_on_dominates_off_for_the_quiet_tenant() {
    let off = run(&contended(40_000, false)).unwrap();
    let on = run(&contended(40_000, true)).unwrap();

    let q_off = &off.tenants[1];
    let q_on = &on.tenants[1];
    assert_eq!(q_off.name, "quiet");
    // Same seed → the quiet tenant's offered traffic is identical in both
    // arms; only what the cache does to it differs.
    assert_eq!(q_on.offered, q_off.offered, "arms must see identical arrivals");
    assert!(q_on.accesses > 0 && q_off.accesses > 0);

    let n_off = &off.tenants[0];
    let n_on = &on.tenants[0];
    assert_eq!(n_off.throttled_windows, 0, "disabled arbiter must not throttle");
    assert_eq!(off.throttled_windows, 0);
    assert!(
        n_on.throttled_windows > 0,
        "the arbiter never identified the noisy tenant (scores too low?)"
    );

    assert!(
        q_on.l2_hit_rate > q_off.l2_hit_rate,
        "quiet tenant hit rate must strictly improve under arbitration: \
         on={:.4} off={:.4}",
        q_on.l2_hit_rate,
        q_off.l2_hit_rate
    );
    assert!(
        q_on.l2_pollution_ratio <= q_off.l2_pollution_ratio,
        "quiet tenant pollution must not worsen under arbitration: \
         on={:.4} off={:.4}",
        q_on.l2_pollution_ratio,
        q_off.l2_pollution_ratio
    );
}

#[test]
fn capture_stamps_real_tenant_ids() {
    let path = tmp("tenant-capture.acpctrace");
    let mut spec = contended(2_000, true);
    spec.capture = Some(path.to_str().unwrap().to_string());
    let rep = run(&spec).unwrap();
    assert!(rep.accesses > 0);

    let reader = TraceReader::open(&path).unwrap();
    assert_eq!(reader.version(), 2, "serve captures are v2");
    assert_eq!(reader.tokens(), rep.tokens, "header totals");
    let records: Vec<_> = reader.map(|r| r.unwrap()).collect();
    assert!(!records.is_empty());

    // Tenant ids are *tenant* indices (not worker indices as in the classic
    // coordinator capture): exactly the spec's two tenants appear.
    let tenants: std::collections::BTreeSet<u32> =
        records.iter().map(|r| r.tenant).collect();
    assert_eq!(
        tenants,
        [0u32, 1].into_iter().collect(),
        "capture must carry both tenants' ids"
    );

    // Per-tenant access counts in the capture match the report attribution.
    for (ti, tr) in rep.tenants.iter().enumerate() {
        let n = records.iter().filter(|r| r.tenant == ti as u32).count();
        assert!(n > 0, "tenant {} served nothing", tr.name);
    }
}
