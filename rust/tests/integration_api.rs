//! Integration tests for the public run API: `RunSpec` JSON round-trips,
//! builder validation, report-embedded-spec reproducibility, and the
//! `acpc run --spec` CLI golden path. (Byte-level parity of the Runner
//! against the crate-internal `run_workload`/`run_workload_sharded`
//! delegates is asserted by unit tests inside `api::runner`, which can
//! reach the internals.)

use acpc::api::{RunSpec, Runner, SCHEMA};
use acpc::config::PredictorKind;
use acpc::util::json::Json;

fn tmp_path(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("acpc_api_test");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// A spec with every block populated survives JSON round-trips exactly.
#[test]
fn spec_json_roundtrip_is_lossless() {
    let spec = RunSpec::builder()
        .name("roundtrip")
        .scenario("long-context")
        .policy("acpc")
        .predictor(PredictorKind::Heuristic)
        .accesses(25_000)
        .predict_batch(128)
        .seed(0xDEAD_BEEF_CAFE_F00D) // > 2^53
        .shards(2)
        .adaptive(true)
        .prefetcher("stride")
        .l3_policy("srrip")
        .l2_kb(256)
        .max_live_sessions(6)
        .phase_period(5_000)
        .build()
        .unwrap();
    let j = spec.to_json();
    assert_eq!(j.get("schema").unwrap().as_str(), Some(SCHEMA));
    let text = j.to_pretty();
    let back = RunSpec::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(spec, back);
}

/// The report's embedded resolved spec re-runs to identical stats — the
/// reproducibility contract of `acpc-run-v1`.
#[test]
fn report_embedded_spec_reruns_identically() {
    let spec = RunSpec::builder()
        .scenario("multi-tenant-mix")
        .policy("acpc")
        .predictor(PredictorKind::Heuristic)
        .accesses(50_000)
        .seed(0xF00D)
        .shards(2)
        .adaptive(true)
        .build()
        .unwrap();
    let first = Runner::new(spec).unwrap().run().unwrap();
    let report_json = first.to_json();

    // Re-hydrate the spec exactly as an external consumer would: from the
    // serialized report.
    let embedded = report_json.get("spec").expect("report embeds its spec");
    let respec = RunSpec::from_json(embedded).unwrap();
    let second = Runner::new(respec).unwrap().run().unwrap();

    assert_eq!(
        first.result.report.to_json().to_pretty(),
        second.result.report.to_json().to_pretty(),
        "embedded spec must reproduce the run"
    );
    assert_eq!(first.result.prediction_batches, second.result.prediction_batches);
    assert_eq!(first.result.drift_events, second.result.drift_events);
    assert_eq!(first.predictor_effective, second.predictor_effective);
}

/// Schema stability: the report JSON carries the keys the docs promise.
#[test]
fn report_json_schema() {
    let spec = RunSpec::builder()
        .preset("smoke")
        .policy("lru")
        .predictor(PredictorKind::None)
        .accesses(20_000)
        .build()
        .unwrap();
    let report = Runner::new(spec).unwrap().run().unwrap();
    let j = report.to_json();
    assert_eq!(j.get("schema").unwrap().as_str(), Some("acpc-run-v1"));
    for key in [
        "spec",
        "predictor_effective",
        "metrics",
        "prediction_batches",
        "online_train_steps",
        "wall_secs",
        "accesses_per_sec",
    ] {
        assert!(j.get(key).is_some(), "missing report key {key}");
    }
    assert_eq!(
        j.get("metrics").unwrap().get("accesses").unwrap().as_usize(),
        Some(20_000)
    );
    // Non-adaptive runs carry no adaptation block.
    assert!(j.get("adaptation").is_none());
}

/// Golden test for `acpc run --spec`: the CLI writes a schema-stamped
/// report whose metrics match a library run of the same spec file, and
/// repeat invocations are byte-identical on the deterministic fields.
#[test]
fn cli_run_spec_golden() {
    let spec_path = tmp_path("golden_spec.json");
    let out1 = tmp_path("golden_report_1.json");
    let out2 = tmp_path("golden_report_2.json");
    std::fs::write(
        &spec_path,
        r#"{
  "policy": "acpc",
  "predictor": "heuristic",
  "accesses": 30000,
  "seed": "4242",
  "workload": {"scenario": "decode-heavy"}
}"#,
    )
    .unwrap();

    let argv = |out: &std::path::Path| {
        vec![
            "run".to_string(),
            "--spec".to_string(),
            spec_path.to_string_lossy().into_owned(),
            "--json".to_string(),
            out.to_string_lossy().into_owned(),
        ]
    };
    let code = acpc::cli::run(argv(&out1)).expect("cli run");
    assert_eq!(code, 0);
    let code = acpc::cli::run(argv(&out2)).expect("cli rerun");
    assert_eq!(code, 0);

    let j1 = Json::parse(&std::fs::read_to_string(&out1).unwrap()).unwrap();
    let j2 = Json::parse(&std::fs::read_to_string(&out2).unwrap()).unwrap();
    assert_eq!(j1.get("schema").unwrap().as_str(), Some("acpc-run-v1"));
    assert_eq!(
        j1.get("metrics").unwrap().to_pretty(),
        j2.get("metrics").unwrap().to_pretty(),
        "CLI runs of one spec must be deterministic"
    );
    assert_eq!(
        j1.get("spec").unwrap().to_pretty(),
        j2.get("spec").unwrap().to_pretty()
    );

    // The CLI's metrics must equal a library run of the same file.
    let lib = Runner::from_spec_file(&spec_path).unwrap().run().unwrap();
    assert_eq!(
        j1.get("metrics").unwrap().to_pretty(),
        lib.result.report.to_json().to_pretty()
    );

    // CLI overrides beat the file: --accesses changes the run length.
    let out3 = tmp_path("golden_report_3.json");
    let mut argv3 = argv(&out3);
    argv3.push("--accesses".into());
    argv3.push("10000".into());
    assert_eq!(acpc::cli::run(argv3).unwrap(), 0);
    let j3 = Json::parse(&std::fs::read_to_string(&out3).unwrap()).unwrap();
    assert_eq!(
        j3.get("metrics").unwrap().get("accesses").unwrap().as_usize(),
        Some(10_000)
    );

    for p in [spec_path, out1, out2, out3] {
        std::fs::remove_file(p).ok();
    }
}

/// `acpc run` rejects missing/invalid specs with an error, not a panic.
#[test]
fn cli_run_rejects_bad_specs() {
    // Missing --spec.
    assert!(acpc::cli::run(vec!["run".into()]).is_err());
    // Unknown key in the file.
    let bad = tmp_path("bad_spec.json");
    std::fs::write(&bad, r#"{"polcy": "lru"}"#).unwrap();
    assert!(acpc::cli::run(vec![
        "run".into(),
        "--spec".into(),
        bad.to_string_lossy().into_owned()
    ])
    .is_err());
    std::fs::remove_file(bad).ok();
}
