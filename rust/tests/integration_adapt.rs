//! Integration tests for the adaptive-control subsystem: a passive
//! controller must not perturb the simulation, the drift/event log must be
//! deterministic under a fixed seed, the `acpc adapt` comparison JSON must
//! keep its schema, and the predictor hot-swap plumbing must be
//! metric-transparent when the swapped-in weights are identical.

use acpc::adapt::{run_compare, AdaptiveController, ControllerConfig};
use acpc::config::{ExperimentConfig, PredictorKind};
use acpc::predictor::{HeuristicPredictor, PredictorBox};
use acpc::sim::{run_workload, run_workload_adaptive};

fn scenario_cfg(scenario: &str, accesses: usize, seed: u64) -> ExperimentConfig {
    let mut cfg =
        ExperimentConfig::for_scenario(scenario, "acpc", PredictorKind::Heuristic, seed).unwrap();
    cfg.accesses = accesses;
    cfg
}

/// A controller that only observes (thresholds disabled) must leave the
/// simulation byte-identical to a controller-free run: telemetry taps and
/// the versioned-handle plumbing cannot perturb metrics.
#[test]
fn passive_controller_is_metric_transparent() {
    let cfg = scenario_cfg("multi-tenant-mix", 80_000, 0xA11CE);

    let mut plain_pred = PredictorBox::Heuristic(HeuristicPredictor);
    let mut w1 = cfg.workload();
    let plain = run_workload(&cfg, w1.as_mut(), &mut plain_pred);

    let mut adapt_pred = PredictorBox::Heuristic(HeuristicPredictor);
    let mut controller = AdaptiveController::new(ControllerConfig::passive());
    let mut w2 = cfg.workload();
    let adaptive = run_workload_adaptive(&cfg, w2.as_mut(), &mut adapt_pred, Some(&mut controller));

    assert_eq!(
        plain.report.to_json().to_pretty(),
        adaptive.report.to_json().to_pretty(),
        "passive controller must not change metrics"
    );
    assert_eq!(plain.prediction_batches, adaptive.prediction_batches);
    assert!(adaptive.adapt_windows > 0, "telemetry still collected");
    assert_eq!(adaptive.predictor_swaps, 0);
    assert_eq!(adaptive.drift_events, 0);
    assert_eq!(controller.swap_count(), 0);
}

/// Same seed + same thresholds ⇒ identical drift windows, events and
/// metrics — the whole control loop is wall-clock-free.
#[test]
fn drift_detection_deterministic_under_fixed_seed() {
    let cfg = scenario_cfg("multi-tenant-mix", 120_000, 0xD51F7);
    let ccfg = ControllerConfig::quick();
    let a = run_compare(&cfg, &ccfg, || PredictorBox::Heuristic(HeuristicPredictor));
    let b = run_compare(&cfg, &ccfg, || PredictorBox::Heuristic(HeuristicPredictor));
    assert_eq!(a.summary.drift_windows, b.summary.drift_windows);
    assert_eq!(a.summary.swaps, b.summary.swaps);
    assert_eq!(a.summary.throttled_windows, b.summary.throttled_windows);
    assert_eq!(
        a.adaptive.report.to_json().to_pretty(),
        b.adaptive.report.to_json().to_pretty()
    );
    assert_eq!(a.to_json().to_pretty(), b.to_json().to_pretty());
}

/// The fast-drift scenario must actually trip the detector, and the
/// comparison must quantify a hit-rate delta between the two arms.
#[test]
fn multi_tenant_mix_trips_the_drift_detector() {
    let cfg = scenario_cfg("multi-tenant-mix", 150_000, 0xBEE5);
    let ccfg = ControllerConfig::quick();
    let out = run_compare(&cfg, &ccfg, || PredictorBox::Heuristic(HeuristicPredictor));
    assert!(out.summary.windows_observed > 10);
    assert!(
        out.summary.drift_events >= 1,
        "fast-drift scenario should fire the detector: {:?}",
        out.summary
    );
    assert!(out.hit_rate_delta().is_finite());
    // With only a heuristic predictor the controller adapts by throttling;
    // every event must carry a monotone version stamp.
    let mut last = 0;
    for e in &out.summary.events {
        assert!(e.predictor_version > last, "versions must be monotone: {:?}", out.summary.events);
        last = e.predictor_version;
    }
}

/// `acpc adapt --json` schema: the keys the docs promise must exist.
#[test]
fn adapt_comparison_json_schema() {
    let cfg = scenario_cfg("decode-heavy", 40_000, 7);
    let mut ccfg = ControllerConfig::quick();
    ccfg.window_accesses = 4096;
    let out = run_compare(&cfg, &ccfg, || PredictorBox::Heuristic(HeuristicPredictor));
    let j = out.to_json();
    for key in ["baseline", "adaptive", "adaptation", "deltas"] {
        assert!(j.get(key).is_some(), "missing top-level key {key}");
    }
    let adaptation = j.get("adaptation").unwrap();
    for key in [
        "windows_observed",
        "drift_events",
        "swaps",
        "throttled_windows",
        "online_train_steps",
        "drift_windows",
        "events",
        "windows",
    ] {
        assert!(adaptation.get(key).is_some(), "missing adaptation key {key}");
    }
    let deltas = j.get("deltas").unwrap();
    for key in ["hit_rate", "pollution", "amat"] {
        assert!(deltas.get(key).unwrap().as_f64().is_some(), "delta {key} must be numeric");
    }
    // Windows must serialize with their telemetry fields.
    let windows = adaptation.get("windows").unwrap().as_arr().unwrap();
    assert!(!windows.is_empty());
    for key in ["index", "hit_rate", "pollution", "prefetch_accuracy", "reuse_p50_log2"] {
        assert!(windows[0].get(key).is_some(), "missing window key {key}");
    }
}

/// Hot-swap transparency with the *real* compiled model: a passive
/// controller threading an untouched TCN through the versioned handle must
/// reproduce the plain TCN run exactly (same weights ⇒ same metrics).
/// Skips when the AOT artifacts are absent.
#[test]
fn tcn_hot_swap_plumbing_is_metric_transparent() {
    let Some(dir) = acpc::runtime::artifacts_dir() else {
        eprintln!("SKIP: artifacts not built");
        return;
    };
    let manifest = acpc::runtime::Manifest::load(&dir).unwrap();
    let engine = acpc::runtime::Engine::cpu().unwrap();
    let load = || {
        let rt = acpc::predictor::ModelRuntime::load(&engine, &manifest, "tcn").unwrap();
        PredictorBox::Model(Box::new(rt))
    };
    let mut cfg = scenario_cfg("decode-heavy", 40_000, 0x7C2);
    cfg.predictor = PredictorKind::Tcn;

    let mut plain_pred = load();
    let mut w1 = cfg.workload();
    let plain = run_workload(&cfg, w1.as_mut(), &mut plain_pred);

    let mut adapt_pred = load();
    let mut controller = AdaptiveController::new(ControllerConfig::passive());
    let mut w2 = cfg.workload();
    let adaptive = run_workload_adaptive(&cfg, w2.as_mut(), &mut adapt_pred, Some(&mut controller));

    assert_eq!(
        plain.report.to_json().to_pretty(),
        adaptive.report.to_json().to_pretty(),
        "identical weights through the swap handle must give identical metrics"
    );
}
