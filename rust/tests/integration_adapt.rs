//! Integration tests for the adaptive-control subsystem, driven through
//! the public `RunSpec` → `Runner` API: a passive controller must not
//! perturb the simulation, the drift/event log must be deterministic under
//! a fixed seed, the `acpc adapt` comparison JSON must keep its schema, and
//! the predictor hot-swap plumbing must be metric-transparent when the
//! swapped-in weights are identical.

use acpc::adapt::ControllerConfig;
use acpc::api::{run_compare, AdaptSpec, RunSpec, Runner};
use acpc::config::PredictorKind;
use acpc::predictor::PredictorBox;

fn scenario_spec(scenario: &str, accesses: usize, seed: u64) -> acpc::api::RunSpecBuilder {
    RunSpec::builder()
        .scenario(scenario)
        .policy("acpc")
        .predictor(PredictorKind::Heuristic)
        .accesses(accesses)
        .seed(seed)
}

fn quick_adapt() -> AdaptSpec {
    AdaptSpec::from_config(&ControllerConfig::quick())
}

/// A controller that only observes (thresholds disabled) must leave the
/// simulation byte-identical to a controller-free run: telemetry taps and
/// the versioned-handle plumbing cannot perturb metrics.
#[test]
fn passive_controller_is_metric_transparent() {
    let plain = Runner::new(scenario_spec("multi-tenant-mix", 80_000, 0xA11CE).build().unwrap())
        .unwrap()
        .run()
        .unwrap();
    let adaptive = Runner::new(
        scenario_spec("multi-tenant-mix", 80_000, 0xA11CE)
            .controller(ControllerConfig::passive())
            .build()
            .unwrap(),
    )
    .unwrap()
    .run()
    .unwrap();

    assert_eq!(
        plain.result.report.to_json().to_pretty(),
        adaptive.result.report.to_json().to_pretty(),
        "passive controller must not change metrics"
    );
    assert_eq!(plain.result.prediction_batches, adaptive.result.prediction_batches);
    assert!(adaptive.result.adapt_windows > 0, "telemetry still collected");
    assert_eq!(adaptive.result.predictor_swaps, 0);
    assert_eq!(adaptive.result.drift_events, 0);
    let summary = adaptive.adaptation().expect("adaptive run carries a summary");
    assert_eq!(summary.swaps, 0);
    assert_eq!(adaptive.predictor_effective, "adaptive(heuristic)");
}

/// Same seed + same thresholds ⇒ identical drift windows, events and
/// metrics — the whole control loop is wall-clock-free.
#[test]
fn drift_detection_deterministic_under_fixed_seed() {
    let spec = scenario_spec("multi-tenant-mix", 120_000, 0xD51F7)
        .adaptive_spec(quick_adapt())
        .build()
        .unwrap();
    let a = run_compare(&spec).unwrap();
    let b = run_compare(&spec).unwrap();
    assert_eq!(a.summary.drift_windows, b.summary.drift_windows);
    assert_eq!(a.summary.swaps, b.summary.swaps);
    assert_eq!(a.summary.throttled_windows, b.summary.throttled_windows);
    assert_eq!(
        a.adaptive.report.to_json().to_pretty(),
        b.adaptive.report.to_json().to_pretty()
    );
    assert_eq!(a.to_json().to_pretty(), b.to_json().to_pretty());
}

/// The fast-drift scenario must actually trip the detector, and the
/// comparison must quantify a hit-rate delta between the two arms.
#[test]
fn multi_tenant_mix_trips_the_drift_detector() {
    let spec = scenario_spec("multi-tenant-mix", 150_000, 0xBEE5)
        .adaptive_spec(quick_adapt())
        .build()
        .unwrap();
    let out = run_compare(&spec).unwrap();
    assert!(out.summary.windows_observed > 10);
    assert!(
        out.summary.drift_events >= 1,
        "fast-drift scenario should fire the detector: {:?}",
        out.summary
    );
    assert!(out.hit_rate_delta().is_finite());
    // With only a heuristic predictor the controller adapts by throttling;
    // every event must carry a monotone version stamp.
    let mut last = 0;
    for e in &out.summary.events {
        assert!(e.predictor_version > last, "versions must be monotone: {:?}", out.summary.events);
        last = e.predictor_version;
    }
}

/// `acpc adapt --json` schema: the keys the docs promise must exist; the
/// `--telemetry` series must align with the window log.
#[test]
fn adapt_comparison_json_schema() {
    let spec = scenario_spec("decode-heavy", 40_000, 7)
        .adaptive_spec(AdaptSpec { window_accesses: Some(4096), ..quick_adapt() })
        .build()
        .unwrap();
    let out = run_compare(&spec).unwrap();
    let j = out.to_json();
    for key in ["baseline", "adaptive", "predictor_effective", "adaptation", "deltas"] {
        assert!(j.get(key).is_some(), "missing top-level key {key}");
    }
    // Effective-predictor provenance: what actually ran in each arm.
    assert_eq!(
        j.get("predictor_effective").unwrap().get("baseline").unwrap().as_str(),
        Some("heuristic")
    );
    assert_eq!(
        j.get("predictor_effective").unwrap().get("adaptive").unwrap().as_str(),
        Some("adaptive(heuristic)")
    );
    let adaptation = j.get("adaptation").unwrap();
    for key in [
        "windows_observed",
        "drift_events",
        "swaps",
        "throttled_windows",
        "online_train_steps",
        "drift_windows",
        "events",
        "windows",
    ] {
        assert!(adaptation.get(key).is_some(), "missing adaptation key {key}");
    }
    let deltas = j.get("deltas").unwrap();
    for key in ["hit_rate", "pollution", "amat"] {
        assert!(deltas.get(key).unwrap().as_f64().is_some(), "delta {key} must be numeric");
    }
    // Windows must serialize with their telemetry fields.
    let windows = adaptation.get("windows").unwrap().as_arr().unwrap();
    assert!(!windows.is_empty());
    for key in ["index", "hit_rate", "pollution", "prefetch_accuracy", "reuse_p50_log2"] {
        assert!(windows[0].get(key).is_some(), "missing window key {key}");
    }
    // The columnar telemetry series (acpc adapt --telemetry) aligns with
    // the window log.
    let t = out.summary.telemetry_json();
    assert_eq!(t.get("schema").unwrap().as_str(), Some("acpc-adapt-telemetry-v1"));
    assert_eq!(
        t.get("hit_rate").unwrap().as_arr().unwrap().len(),
        out.summary.windows.len()
    );
}

/// Hot-swap transparency with the *real* compiled model: a passive
/// controller threading an untouched TCN through the versioned handle must
/// reproduce the plain TCN run exactly (same weights ⇒ same metrics).
/// Skips when the AOT artifacts are absent.
#[test]
fn tcn_hot_swap_plumbing_is_metric_transparent() {
    let Some(dir) = acpc::runtime::artifacts_dir() else {
        eprintln!("SKIP: artifacts not built");
        return;
    };
    let manifest = acpc::runtime::Manifest::load(&dir).unwrap();
    let engine = acpc::runtime::Engine::cpu().unwrap();
    let load = || {
        let rt = acpc::predictor::ModelRuntime::load(&engine, &manifest, "tcn").unwrap();
        PredictorBox::Model(Box::new(rt))
    };
    let base = || {
        RunSpec::builder()
            .scenario("decode-heavy")
            .policy("acpc")
            .predictor(PredictorKind::Tcn)
            .accesses(40_000)
            .seed(0x7C2)
    };

    let plain = Runner::new(base().build().unwrap())
        .unwrap()
        .with_predictor(load())
        .run()
        .unwrap();
    let adaptive = Runner::new(
        base().controller(ControllerConfig::passive()).build().unwrap(),
    )
    .unwrap()
    .with_predictor(load())
    .run()
    .unwrap();

    assert_eq!(
        plain.result.report.to_json().to_pretty(),
        adaptive.result.report.to_json().to_pretty(),
        "identical weights through the swap handle must give identical metrics"
    );
}
