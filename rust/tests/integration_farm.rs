//! Integration tests for the experiment farm: content-addressed caching
//! end-to-end through the `Runner`, the manifest CLI, and the store. The
//! acceptance contract of the farm is asserted here: a second identical
//! invocation completes with 100% cache hits, zero re-simulation, and
//! byte-identical reports.

use acpc::api::{CacheMode, ReportStore, RunSpec, Runner};
use acpc::config::PredictorKind;
use acpc::util::json::Json;

fn tmp_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("acpc_farm_itest").join(name);
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn spec(policy: &str, seed: u64, shards: usize) -> RunSpec {
    RunSpec::builder()
        .scenario("decode-heavy")
        .policy(policy)
        .predictor(if policy == "acpc" { PredictorKind::Heuristic } else { PredictorKind::None })
        .accesses(20_000)
        .seed(seed)
        .shards(shards)
        .build()
        .unwrap()
}

/// A cache hit must be byte-for-byte the report the cold run produced —
/// for the single-shard path and the set-sharded path alike.
#[test]
fn warm_runner_hit_is_byte_identical_single_and_sharded() {
    let dir = tmp_dir("runner_hits");
    let store = ReportStore::open(dir.join("store"));
    for shards in [1usize, 2] {
        let mk = || {
            Runner::new(spec("acpc", 0xBEEF, shards))
                .unwrap()
                .with_store(store.clone(), CacheMode::ReadWrite)
        };
        let (cold, was_cached) = mk().run_cached().unwrap();
        assert!(!was_cached, "{shards} shards: first run must simulate");
        let (warm, was_cached) = mk().run_cached().unwrap();
        assert!(was_cached, "{shards} shards: second run must hit");
        assert_eq!(
            cold.to_json().to_pretty(),
            warm.to_json().to_pretty(),
            "{shards} shards: hit must be byte-identical"
        );
    }
    // Distinct shard counts resolve to distinct specs → distinct entries.
    assert_eq!(store.len(), 2);
}

/// `CacheMode::Off` never reads nor writes; `Read` serves hits but leaves
/// misses unpersisted.
#[test]
fn cache_modes_gate_reads_and_writes() {
    let dir = tmp_dir("modes");
    let store = ReportStore::open(dir.join("store"));
    let mk = |mode| {
        Runner::new(spec("lru", 7, 1)).unwrap().with_store(store.clone(), mode)
    };
    let (_, cached) = mk(CacheMode::Off).run_cached().unwrap();
    assert!(!cached);
    assert!(store.is_empty(), "Off must not write");
    let (_, cached) = mk(CacheMode::Read).run_cached().unwrap();
    assert!(!cached);
    assert!(store.is_empty(), "Read must not write");
    let (_, cached) = mk(CacheMode::ReadWrite).run_cached().unwrap();
    assert!(!cached);
    assert_eq!(store.len(), 1);
    let (_, cached) = mk(CacheMode::Read).run_cached().unwrap();
    assert!(cached, "Read serves existing entries");
}

/// The acceptance contract end-to-end through the CLI: the second
/// identical `acpc run --manifest` completes with 100% cache hits and
/// byte-identical cell reports.
#[test]
fn warm_manifest_cli_run_is_all_hits_and_byte_identical() {
    let dir = tmp_dir("cli_manifest");
    let manifest = dir.join("runs");
    std::fs::create_dir_all(&manifest).unwrap();
    std::fs::write(
        manifest.join("grid.json"),
        r#"{"runs": [
            {"policy": "lru", "predictor": "none",
             "workload": {"scenario": "decode-heavy"}, "accesses": 20000},
            {"policy": "acpc", "predictor": "heuristic",
             "workload": {"scenario": "decode-heavy"}, "accesses": 20000}
        ]}"#,
    )
    .unwrap();
    let store = dir.join("store");
    let out1 = dir.join("farm1.json");
    let out2 = dir.join("farm2.json");

    let invoke = |out: &std::path::Path| {
        let argv: Vec<String> = [
            "run",
            "--manifest",
            manifest.to_str().unwrap(),
            "--store",
            store.to_str().unwrap(),
            "--json",
            out.to_str().unwrap(),
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        acpc::cli::run(argv).unwrap()
    };
    assert_eq!(invoke(&out1), 0);
    assert_eq!(invoke(&out2), 0);

    let parse = |p: &std::path::Path| {
        Json::parse(&std::fs::read_to_string(p).unwrap()).unwrap()
    };
    let (j1, j2) = (parse(&out1), parse(&out2));
    for j in [&j1, &j2] {
        assert_eq!(j.get("schema").unwrap().as_str(), Some("acpc-farm-v1"));
        assert_eq!(j.get("cells").unwrap().as_arr().unwrap().len(), 2);
    }
    let cells1 = j1.get("cells").unwrap().as_arr().unwrap();
    let cells2 = j2.get("cells").unwrap().as_arr().unwrap();
    for (c1, c2) in cells1.iter().zip(cells2) {
        assert_eq!(c1.get("cached").unwrap().as_bool(), Some(false), "cold run simulates");
        assert_eq!(c2.get("cached").unwrap().as_bool(), Some(true), "warm run is 100% hits");
        assert_eq!(c1.get("spec_hash").unwrap().as_str(), c2.get("spec_hash").unwrap().as_str());
        assert_eq!(
            c1.get("report").unwrap().to_pretty(),
            c2.get("report").unwrap().to_pretty(),
            "cached report must be byte-identical to the fresh one"
        );
    }
}

/// Deleting the artifacts-independent store between invocations brings the
/// simulation back — the cache is an accelerator, not a dependency.
#[test]
fn cleared_store_falls_back_to_simulation() {
    let dir = tmp_dir("clear");
    let store_dir = dir.join("store");
    let store = ReportStore::open(&store_dir);
    let mk = || {
        Runner::new(spec("lru", 11, 1)).unwrap().with_store(store.clone(), CacheMode::ReadWrite)
    };
    let (first, _) = mk().run_cached().unwrap();
    std::fs::remove_dir_all(&store_dir).unwrap();
    let (again, cached) = mk().run_cached().unwrap();
    assert!(!cached, "emptied store must re-simulate");
    assert_eq!(first.to_json().to_pretty(), again.to_json().to_pretty());
}
