//! Property-based tests over replacement policies and the cache simulator:
//! structural invariants that must hold for ANY access stream.

use acpc::mem::{Cache, CacheConfig, Hierarchy, HierarchyConfig};
use acpc::policy::{make_policy, AccessMeta, POLICY_NAMES};
use acpc::trace::{GeneratorConfig, StreamKind, TraceGenerator};
use acpc::util::proptest::prop_check;

/// Drive a single cache with a random access/fill/invalidate stream and
/// check bookkeeping invariants afterwards.
#[test]
fn prop_cache_bookkeeping_invariants() {
    prop_check("cache bookkeeping", 60, |g| {
        let assoc = *g.pick(&[2usize, 4, 8]);
        let size_kb = *g.pick(&[4u64, 8, 16]);
        let policy_name = *g.pick(POLICY_NAMES);
        let cfg = CacheConfig::new("t", size_kb * 1024, assoc);
        let policy = make_policy(policy_name, cfg.num_sets(), assoc, g.u64(0, 1 << 30)).unwrap();
        let mut c = Cache::new(cfg, policy);

        let lines = g.vec_u64(200, 3000, 0, 4096);
        let mut fills = 0u64;
        for (i, &line) in lines.iter().enumerate() {
            let mut meta = AccessMeta::demand(line, line % 13, StreamKind::Weight);
            meta.next_use = Some(i as u64 + 1 + line % 97); // keep belady fed
            let is_pf = i % 7 == 0;
            if is_pf {
                if c.probe(line).is_none() {
                    let mut m = meta;
                    m.is_prefetch = true;
                    c.fill(line, &m, false);
                    fills += 1;
                }
            } else if c.access(line, &meta, i % 5 == 0) == acpc::mem::cache::Lookup::Miss {
                c.fill(line, &meta, i % 5 == 0);
                fills += 1;
            }
            if i % 31 == 0 {
                c.invalidate(line);
            }
        }
        let st = &c.stats;
        // Conservation: hits + misses = demand accesses.
        if st.demand_hits + st.demand_misses != st.demand_accesses {
            return Err(format!(
                "hits {} + misses {} != accesses {}",
                st.demand_hits, st.demand_misses, st.demand_accesses
            ));
        }
        // Evictions can never exceed fills.
        if st.evictions > fills {
            return Err(format!("evictions {} > fills {fills}", st.evictions));
        }
        // Dead prefetch evictions bounded by prefetch fills.
        if st.dead_prefetch_evictions > st.prefetch_fills {
            return Err(format!(
                "dead pf {} > pf fills {}",
                st.dead_prefetch_evictions, st.prefetch_fills
            ));
        }
        // Useful prefetches bounded by prefetch fills.
        if st.prefetch_useful > st.prefetch_fills {
            return Err("useful > issued".into());
        }
        // Occupancy within capacity.
        if !(0.0..=1.0).contains(&c.occupancy()) {
            return Err(format!("occupancy {}", c.occupancy()));
        }
        Ok(())
    });
}

/// A line that was just filled must be resident; a hit immediately after a
/// fill must be a hit — for every policy.
#[test]
fn prop_fill_then_hit() {
    prop_check("fill-then-hit", 40, |g| {
        let policy_name = *g.pick(POLICY_NAMES);
        let cfg = CacheConfig::new("t", 8 * 1024, 4);
        let policy = make_policy(policy_name, cfg.num_sets(), 4, 7).unwrap();
        let mut c = Cache::new(cfg, policy);
        for _ in 0..300 {
            let line = g.u64(0, 1 << 14);
            let mut meta = AccessMeta::demand(line, 3, StreamKind::KvRead);
            meta.next_use = Some(1);
            if c.access(line, &meta, false) == acpc::mem::cache::Lookup::Miss {
                c.fill(line, &meta, false);
            }
            if c.access(line, &meta, false) != acpc::mem::cache::Lookup::Hit {
                return Err(format!("{policy_name}: just-filled line {line:#x} missed"));
            }
        }
        Ok(())
    });
}

/// Larger caches never hit less than smaller ones under LRU (inclusion
/// property transferred to full-cache granularity, same assoc scaling).
#[test]
fn prop_lru_monotone_in_capacity() {
    prop_check("lru capacity monotonicity", 15, |g| {
        let seed = g.u64(0, 1 << 40);
        let trace = TraceGenerator::new(GeneratorConfig::tiny(seed)).generate(30_000);
        let mut rates = Vec::new();
        for kb in [8u64, 32, 128] {
            let cfg = CacheConfig::new("t", kb * 1024, 8);
            let policy = make_policy("lru", cfg.num_sets(), 8, 1).unwrap();
            let mut c = Cache::new(cfg, policy);
            for a in &trace {
                let meta = AccessMeta::demand(a.line(), a.pc, a.kind);
                if c.access(a.line(), &meta, a.is_write) == acpc::mem::cache::Lookup::Miss {
                    c.fill(a.line(), &meta, a.is_write);
                }
            }
            rates.push(c.stats.hit_rate());
        }
        if !(rates[0] <= rates[1] + 1e-9 && rates[1] <= rates[2] + 1e-9) {
            return Err(format!("hit rates not monotone in capacity: {rates:?}"));
        }
        Ok(())
    });
}

/// The full hierarchy never loses accesses, and AMAT stays within the
/// physically possible [L1 latency, DRAM latency] band — any policy, any
/// prefetcher, any profile knob combination.
#[test]
fn prop_hierarchy_amat_bounds() {
    prop_check("hierarchy amat bounds", 25, |g| {
        let policy = *g.pick(&["lru", "srrip", "dip", "ship", "acpc", "mlpredict"]);
        let prefetcher = *g.pick(&["none", "nextline", "stride", "correlation", "composite"]);
        let mut hcfg = HierarchyConfig::scaled();
        hcfg.prefetcher = prefetcher.to_string();
        let mut h = Hierarchy::new(hcfg, policy);
        let seed = g.u64(0, 1 << 40);
        let n = g.usize(5_000, 30_000);
        let mut gen = TraceGenerator::new(GeneratorConfig::tiny(seed));
        for _ in 0..n {
            let a = gen.next_access();
            let meta = AccessMeta::demand(a.line(), a.pc, a.kind);
            h.access(&a, &meta);
        }
        if h.accesses != n as u64 {
            return Err(format!("lost accesses: {} != {n}", h.accesses));
        }
        let amat = h.amat();
        let lo = h.latency_of(acpc::mem::ServiceLevel::L1) as f64;
        let hi = h.latency_of(acpc::mem::ServiceLevel::Dram) as f64;
        if !(lo..=hi).contains(&amat) {
            return Err(format!("{policy}/{prefetcher}: AMAT {amat} outside [{lo}, {hi}]"));
        }
        Ok(())
    });
}

/// Utility updates must never corrupt residency: after update_utility on a
/// random line, probes still find exactly the lines that were resident.
#[test]
fn prop_utility_updates_preserve_residency() {
    prop_check("utility updates preserve residency", 30, |g| {
        let mut hcfg = HierarchyConfig::scaled();
        hcfg.prefetcher = "none".into();
        let mut h = Hierarchy::new(hcfg, "acpc");
        let mut gen = TraceGenerator::new(GeneratorConfig::tiny(g.u64(0, 1 << 30)));
        let mut resident_checks = Vec::new();
        for i in 0..5_000 {
            let a = gen.next_access();
            let meta = AccessMeta::demand(a.line(), a.pc, a.kind);
            h.access(&a, &meta);
            h.update_utility(a.line(), g.f64(0.0, 1.0) as f32);
            if i % 500 == 0 {
                resident_checks.push(a.line());
                // Just accessed → must be resident in L1 (and thus findable).
                if h.l1.probe(a.line()).is_none() {
                    return Err(format!("line {:#x} vanished from L1", a.line()));
                }
            }
        }
        Ok(())
    });
}
