//! Integration tests: the full simulation pipeline (no artifacts required)
//! — policy orderings the paper's narrative depends on, metric coherence,
//! config plumbing, oracle dominance.

use acpc::config::{ExperimentConfig, PredictorKind};
use acpc::predictor::{HeuristicPredictor, PredictorBox};
use acpc::sim::run_experiment;

fn run(policy: &str, accesses: usize, heuristic: bool) -> acpc::sim::SimResult {
    let kind = if heuristic { PredictorKind::Heuristic } else { PredictorKind::None };
    let mut cfg = ExperimentConfig::table1(policy, kind);
    cfg.accesses = accesses;
    let mut p =
        if heuristic { PredictorBox::Heuristic(HeuristicPredictor) } else { PredictorBox::None };
    run_experiment(&cfg, &mut p)
}

/// The paper's core qualitative claims on the full (non-tiny) workload:
/// ACPC beats LRU on hit rate AND pollution; SRRIP beats LRU on hit rate.
#[test]
fn paper_orderings_hold_on_full_workload() {
    let n = 300_000;
    let lru = run("lru", n, false);
    let srrip = run("srrip", n, false);
    let acpc = run("acpc", n, true);

    assert!(
        srrip.report.l2_hit_rate > lru.report.l2_hit_rate,
        "srrip {:.3} vs lru {:.3}",
        srrip.report.l2_hit_rate,
        lru.report.l2_hit_rate
    );
    assert!(
        acpc.report.l2_hit_rate > lru.report.l2_hit_rate + 0.01,
        "acpc {:.3} vs lru {:.3}",
        acpc.report.l2_hit_rate,
        lru.report.l2_hit_rate
    );
    assert!(
        acpc.report.l2_pollution_ratio < lru.report.l2_pollution_ratio * 0.6,
        "pollution acpc {:.3} vs lru {:.3}",
        acpc.report.l2_pollution_ratio,
        lru.report.l2_pollution_ratio
    );
    // Miss-penalty reduction positive for the better policies.
    assert!(acpc.report.miss_penalty_reduction_vs(&lru.report).expect("lru misses") > 0.0);
}

/// AMAT must decrease as hit rates increase (metric coherence).
#[test]
fn amat_tracks_hit_rate() {
    let n = 200_000;
    let lru = run("lru", n, false);
    let acpc = run("acpc", n, true);
    assert!(acpc.report.l2_hit_rate > lru.report.l2_hit_rate);
    assert!(acpc.report.amat < lru.report.amat, "{} vs {}", acpc.report.amat, lru.report.amat);
}

/// Belady dominates every realizable policy on L2 hit rate.
#[test]
fn belady_dominates_realizable_policies() {
    let n = 150_000;
    let bel = run("belady", n, false);
    for policy in ["lru", "srrip", "dip"] {
        let r = run(policy, n, false);
        assert!(
            bel.report.l2_hit_rate >= r.report.l2_hit_rate - 0.01,
            "belady {:.4} vs {policy} {:.4}",
            bel.report.l2_hit_rate,
            r.report.l2_hit_rate
        );
    }
}

/// Prefetching must help hit rate under LRU (useful prefetches exist) while
/// creating the pollution ACPC then removes.
#[test]
fn prefetcher_tradeoff_visible() {
    let n = 200_000;
    let mut with_pf = ExperimentConfig::table1("lru", PredictorKind::None);
    with_pf.accesses = n;
    let mut no_pf = with_pf.clone();
    no_pf.hierarchy.prefetcher = "none".into();
    let w = run_experiment(&with_pf, &mut PredictorBox::None);
    let wo = run_experiment(&no_pf, &mut PredictorBox::None);
    // Prefetching produces nonzero pollution…
    assert!(w.report.l2_pollution_ratio > 0.02);
    assert_eq!(wo.report.l2_pollution_ratio, 0.0);
    // …and nonzero useful coverage (accuracy defined).
    assert!(w.report.l2_prefetch_accuracy > 0.05);
}

/// Config-file plumbing end-to-end: JSON overrides change the simulation.
#[test]
fn config_file_roundtrip() {
    let dir = std::env::temp_dir().join("acpc_cfg_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("exp.json");
    std::fs::write(
        &path,
        r#"{"preset": "smoke", "policy": "srrip", "accesses": 30000,
            "hierarchy": {"prefetcher": "stride"},
            "workload": {"profile": "t5", "max_ctx": 128}}"#,
    )
    .unwrap();
    let cfg = ExperimentConfig::from_file(&path).unwrap();
    assert_eq!(cfg.policy, "srrip");
    assert_eq!(cfg.accesses, 30_000);
    assert_eq!(cfg.generator.profile.name, "t5ish");
    let r = run_experiment(&cfg, &mut PredictorBox::None);
    assert_eq!(r.report.accesses, 30_000);
    std::fs::remove_file(path).ok();
}

/// Different workload profiles produce materially different cache behaviour
/// (the generator knobs are live, not cosmetic).
#[test]
fn profiles_differ_materially() {
    let mut rates = Vec::new();
    for profile in ["gpt3ish", "llama2ish", "t5ish"] {
        let mut cfg = ExperimentConfig::table1("lru", PredictorKind::None);
        cfg.accesses = 150_000;
        let p = acpc::trace::ModelProfile::by_name(profile).unwrap();
        cfg.generator = acpc::trace::GeneratorConfig::new(p, cfg.seed);
        let r = run_experiment(&cfg, &mut PredictorBox::None);
        rates.push(r.report.l2_hit_rate);
    }
    let spread = rates.iter().cloned().fold(f64::MIN, f64::max)
        - rates.iter().cloned().fold(f64::MAX, f64::min);
    assert!(spread > 0.01, "profiles indistinguishable: {rates:?}");
}

/// Seeds matter and are honored end-to-end.
#[test]
fn seed_sensitivity_and_reproducibility() {
    let mut a = ExperimentConfig::table1("lru", PredictorKind::None);
    a.accesses = 60_000;
    let mut b = a.clone();
    b.seed ^= 0xFFFF;
    b.generator.seed = b.seed;
    let ra = run_experiment(&a, &mut PredictorBox::None);
    let ra2 = run_experiment(&a, &mut PredictorBox::None);
    let rb = run_experiment(&b, &mut PredictorBox::None);
    assert_eq!(ra.report.l2_miss_cycles, ra2.report.l2_miss_cycles);
    assert_ne!(ra.report.l2_miss_cycles, rb.report.l2_miss_cycles);
}
