//! Integration tests: the full simulation pipeline through the public
//! `RunSpec` → `Runner` API (no artifacts required) — policy orderings the
//! paper's narrative depends on, metric coherence, spec-file plumbing,
//! oracle dominance.

use acpc::api::{RunReport, RunSpec, Runner};
use acpc::config::PredictorKind;

fn run(policy: &str, accesses: usize, heuristic: bool) -> RunReport {
    let kind = if heuristic { PredictorKind::Heuristic } else { PredictorKind::None };
    let spec = RunSpec::builder()
        .policy(policy)
        .predictor(kind)
        .accesses(accesses)
        .build()
        .expect("valid spec");
    Runner::new(spec).expect("resolve").run().expect("run")
}

/// The paper's core qualitative claims on the full (non-tiny) workload:
/// ACPC beats LRU on hit rate AND pollution; SRRIP beats LRU on hit rate.
#[test]
fn paper_orderings_hold_on_full_workload() {
    let n = 300_000;
    let lru = run("lru", n, false);
    let srrip = run("srrip", n, false);
    let acpc = run("acpc", n, true);

    assert!(
        srrip.result.report.l2_hit_rate > lru.result.report.l2_hit_rate,
        "srrip {:.3} vs lru {:.3}",
        srrip.result.report.l2_hit_rate,
        lru.result.report.l2_hit_rate
    );
    assert!(
        acpc.result.report.l2_hit_rate > lru.result.report.l2_hit_rate + 0.01,
        "acpc {:.3} vs lru {:.3}",
        acpc.result.report.l2_hit_rate,
        lru.result.report.l2_hit_rate
    );
    assert!(
        acpc.result.report.l2_pollution_ratio < lru.result.report.l2_pollution_ratio * 0.6,
        "pollution acpc {:.3} vs lru {:.3}",
        acpc.result.report.l2_pollution_ratio,
        lru.result.report.l2_pollution_ratio
    );
    // Miss-penalty reduction positive for the better policies.
    assert!(
        acpc.result
            .report
            .miss_penalty_reduction_vs(&lru.result.report)
            .expect("lru misses")
            > 0.0
    );
}

/// AMAT must decrease as hit rates increase (metric coherence).
#[test]
fn amat_tracks_hit_rate() {
    let n = 200_000;
    let lru = run("lru", n, false);
    let acpc = run("acpc", n, true);
    assert!(acpc.result.report.l2_hit_rate > lru.result.report.l2_hit_rate);
    assert!(
        acpc.result.report.amat < lru.result.report.amat,
        "{} vs {}",
        acpc.result.report.amat,
        lru.result.report.amat
    );
}

/// Belady dominates every realizable policy on L2 hit rate.
#[test]
fn belady_dominates_realizable_policies() {
    let n = 150_000;
    let bel = run("belady", n, false);
    for policy in ["lru", "srrip", "dip"] {
        let r = run(policy, n, false);
        assert!(
            bel.result.report.l2_hit_rate >= r.result.report.l2_hit_rate - 0.01,
            "belady {:.4} vs {policy} {:.4}",
            bel.result.report.l2_hit_rate,
            r.result.report.l2_hit_rate
        );
    }
}

/// Prefetching must help hit rate under LRU (useful prefetches exist) while
/// creating the pollution ACPC then removes.
#[test]
fn prefetcher_tradeoff_visible() {
    let n = 200_000;
    let with_pf = run("lru", n, false);
    let no_pf_spec = RunSpec::builder()
        .policy("lru")
        .predictor(PredictorKind::None)
        .accesses(n)
        .prefetcher("none")
        .build()
        .unwrap();
    let no_pf = Runner::new(no_pf_spec).unwrap().run().unwrap();
    // Prefetching produces nonzero pollution…
    assert!(with_pf.result.report.l2_pollution_ratio > 0.02);
    assert_eq!(no_pf.result.report.l2_pollution_ratio, 0.0);
    // …and nonzero useful coverage (accuracy defined).
    assert!(with_pf.result.report.l2_prefetch_accuracy > 0.05);
}

/// Spec-file plumbing end-to-end: a JSON spec changes the simulation, and
/// the legacy `--config` format is a working subset of the spec format.
#[test]
fn spec_file_roundtrip() {
    let dir = std::env::temp_dir().join("acpc_spec_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("exp.json");
    std::fs::write(
        &path,
        r#"{"preset": "smoke", "policy": "srrip", "predictor": "none", "accesses": 30000,
            "hierarchy": {"prefetcher": "stride"},
            "workload": {"profile": "t5", "max_ctx": 128}}"#,
    )
    .unwrap();
    let spec = RunSpec::from_file(&path).unwrap();
    let runner = Runner::new(spec).unwrap();
    assert_eq!(runner.spec().policy, "srrip");
    assert_eq!(runner.spec().accesses, Some(30_000));
    let r = runner.run().unwrap();
    assert_eq!(r.result.report.accesses, 30_000);
    assert_eq!(r.spec.profile.as_deref(), Some("t5"));
    std::fs::remove_file(path).ok();
}

/// Different workload profiles produce materially different cache behaviour
/// (the generator knobs are live, not cosmetic).
#[test]
fn profiles_differ_materially() {
    let mut rates = Vec::new();
    for profile in ["gpt3ish", "llama2ish", "t5ish"] {
        let spec = RunSpec::builder()
            .policy("lru")
            .predictor(PredictorKind::None)
            .profile(profile)
            .accesses(150_000)
            .build()
            .unwrap();
        let r = Runner::new(spec).unwrap().run().unwrap();
        rates.push(r.result.report.l2_hit_rate);
    }
    let spread = rates.iter().cloned().fold(f64::MIN, f64::max)
        - rates.iter().cloned().fold(f64::MAX, f64::min);
    assert!(spread > 0.01, "profiles indistinguishable: {rates:?}");
}

/// Seeds matter and are honored end-to-end.
#[test]
fn seed_sensitivity_and_reproducibility() {
    let mk = |seed: u64| {
        let spec = RunSpec::builder()
            .policy("lru")
            .predictor(PredictorKind::None)
            .accesses(60_000)
            .seed(seed)
            .build()
            .unwrap();
        Runner::new(spec).unwrap().run().unwrap()
    };
    let ra = mk(0xAC9C_2025);
    let ra2 = mk(0xAC9C_2025);
    let rb = mk(0xAC9C_2025 ^ 0xFFFF);
    assert_eq!(ra.result.report.l2_miss_cycles, ra2.result.report.l2_miss_cycles);
    assert_ne!(ra.result.report.l2_miss_cycles, rb.result.report.l2_miss_cycles);
}
