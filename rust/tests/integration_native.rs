//! Differential tests: the native inference kernel against the PJRT
//! reference, on the real AOT artifacts.
//!
//! Every test is artifact-gated (prints `SKIP` and returns when
//! `artifacts/` is absent — CI stage order) and loud-fails on any runtime
//! error once the artifacts exist. Together they pin the tentpole parity
//! guarantees: per-element agreement within 1e-5 across *all* manifest
//! models, on padded-tail batch shapes, after train steps (the re-snapshot
//! path), and on randomized weights (seeded fuzz through `set_params`).

use acpc::predictor::{Backend, ModelRuntime, ReusePredictor};
use acpc::runtime::{Engine, Manifest, NativeModel, ParamStore};

const TOL: f32 = 1e-5;

fn load_manifest() -> Option<Manifest> {
    let dir = acpc::runtime::artifacts_dir()?;
    Manifest::load(&dir).ok()
}

fn splitmix(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Uniform in [-scale, scale).
fn unit(state: &mut u64, scale: f32) -> f32 {
    let u = (splitmix(state) >> 40) as f32 / (1u64 << 24) as f32;
    (2.0 * u - 1.0) * scale
}

/// Deterministic feature-like input rows (non-negative, mixed zero/nonzero
/// so the kernel's zero-skip path is exercised).
fn input_rows(n: usize, row: usize, seed: u64) -> Vec<f32> {
    let mut state = seed;
    (0..n * row)
        .map(|_| {
            let v = unit(&mut state, 1.0);
            if v < -0.5 {
                0.0
            } else {
                v.abs()
            }
        })
        .collect()
}

fn assert_close(name: &str, native: &[f32], pjrt: &[f32]) {
    assert_eq!(native.len(), pjrt.len());
    for (i, (a, b)) in native.iter().zip(pjrt).enumerate() {
        assert!(
            (a - b).abs() <= TOL,
            "{name}: row {i}: native {a} vs pjrt {b} (|Δ| = {})",
            (a - b).abs()
        );
    }
}

/// Native ≡ PJRT on every model the manifest ships, with a batch size that
/// forces the PJRT backend to zero-pad its tail chunk (the native kernel
/// takes arbitrary n with no padding at all).
#[test]
fn native_matches_pjrt_on_every_manifest_model() {
    let Some(manifest) = load_manifest() else {
        eprintln!("SKIP: artifacts not built");
        return;
    };
    let engine = Engine::cpu().unwrap();
    for name in manifest.models.keys() {
        let mut rt = ModelRuntime::load(&engine, &manifest, name).unwrap();
        let row = rt.row_elems();
        let n = rt.infer_batch * 3 / 2;
        let x = input_rows(n, row, 0xD1FF ^ name.len() as u64);
        assert_eq!(rt.backend(), Backend::Native, "native is the default");
        let native = rt.predict(&x, n);
        rt.set_backend(Backend::Pjrt);
        let pjrt = rt.predict(&x, n);
        assert_close(name, &native, &pjrt);
        // The standalone kernel (what serve/sweep workers run) agrees too.
        let mut solo = NativeModel::from_params(&rt.mm, &rt.store).unwrap();
        let mut out = Vec::new();
        solo.predict_into(&x, n, &mut out);
        assert_close(&format!("{name} (standalone)"), &out, &pjrt);
    }
}

/// After PJRT train steps the runtime must re-snapshot the native weights:
/// predictions agree on the *trained* parameters, and the snapshot version
/// tracks the Adam step.
#[test]
fn native_matches_pjrt_after_train_steps() {
    let Some(manifest) = load_manifest() else {
        eprintln!("SKIP: artifacts not built");
        return;
    };
    let engine = Engine::cpu().unwrap();
    let mut rt = ModelRuntime::load(&engine, &manifest, "tcn").unwrap();
    let row = rt.row_elems();
    let v0 = rt.native_snapshot().unwrap().version();

    let b = rt.mm.train.batch;
    let x = input_rows(b, row, 0x7EA1);
    let y: Vec<f32> = (0..b).map(|i| (i % 2) as f32).collect();
    for _ in 0..3 {
        rt.train_step(x.clone(), y.clone()).unwrap();
    }
    assert_eq!(
        rt.native_snapshot().unwrap().version(),
        v0 + 3,
        "snapshot version must track the Adam step"
    );

    let n = rt.infer_batch + 7;
    let probe = input_rows(n, row, 0xBEEF);
    let native = rt.predict(&probe, n);
    rt.set_backend(Backend::Pjrt);
    let pjrt = rt.predict(&probe, n);
    assert_close("tcn post-train", &native, &pjrt);
}

/// Seeded random-weight fuzz: inject random `ParamStore` contents (through
/// the same `set_params` hook the checkpoint loader uses) and require the
/// two backends to agree on the arbitrary weights — not just the shipped
/// initialization.
#[test]
fn native_matches_pjrt_on_random_weights() {
    let Some(manifest) = load_manifest() else {
        eprintln!("SKIP: artifacts not built");
        return;
    };
    let engine = Engine::cpu().unwrap();
    for name in manifest.models.keys() {
        let mut rt = ModelRuntime::load(&engine, &manifest, name).unwrap();
        let mm = rt.mm.clone();
        let row = rt.row_elems();
        for seed in [1u64, 2, 3] {
            let mut state = seed ^ 0xF022_5EED_0000_0001;
            let bytes: Vec<u8> = (0..mm.total_param_elems())
                .flat_map(|_| unit(&mut state, 0.3).to_le_bytes())
                .collect();
            let store = ParamStore::from_bytes(&mm, &bytes).unwrap();
            rt.set_params(store);
            let n = rt.infer_batch / 2 + 3;
            let x = input_rows(n, row, seed.wrapping_mul(0x5DEECE66D));
            rt.set_backend(Backend::Native);
            let native = rt.predict(&x, n);
            rt.set_backend(Backend::Pjrt);
            let pjrt = rt.predict(&x, n);
            assert_close(&format!("{name} fuzz seed {seed}"), &native, &pjrt);
        }
    }
}
