//! Integration tests over the PJRT runtime + AOT artifacts: numerical
//! contracts between the compiled HLO and the rust data pipeline. These
//! tests skip (loudly) when `make artifacts` has not run.

use acpc::predictor::{Dataset, GeometryHints, ModelRuntime, PredictorBox, ReusePredictor};
use acpc::runtime::{artifacts_dir, Engine, Manifest};
use acpc::trace::{GeneratorConfig, TraceGenerator};
use acpc::training::{bce, eval_split, implicit_loss, train, ImplicitKind, TrainConfig};

macro_rules! need_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("SKIP: artifacts/ not built");
                return;
            }
        }
    };
}

fn mk_dataset(window: usize, n: usize, seed: u64) -> (Dataset, acpc::predictor::Split) {
    let gcfg = GeneratorConfig::tiny(seed);
    let geom = GeometryHints::from_generator(&gcfg);
    let trace = TraceGenerator::new(gcfg).generate(n);
    let ds = Dataset::build(&trace, window, geom, 2048, 4);
    let split = ds.split(seed);
    (ds, split)
}

/// All four models load, infer with valid probabilities, and train with
/// finite loss.
#[test]
fn all_artifact_models_roundtrip() {
    let dir = need_artifacts!();
    let manifest = Manifest::load(&dir).unwrap();
    let engine = Engine::cpu().unwrap();
    for name in ["tcn", "tcn_flat", "tcn_short", "dnn"] {
        let mut rt = ModelRuntime::load(&engine, &manifest, name).unwrap();
        let row = rt.row_elems();
        let probs = rt.predict(&vec![0.2; 8 * row], 8);
        assert_eq!(probs.len(), 8, "{name}");
        for &p in &probs {
            assert!((0.0..=1.0).contains(&p), "{name}: {p}");
        }
        let b = rt.mm.train.batch;
        let loss = rt.train_step(vec![0.2; b * row], vec![1.0; b]).unwrap();
        assert!(loss.is_finite(), "{name}");
    }
}

/// The compiled eval loss must agree with a rust-side BCE computed from the
/// compiled inference probabilities (two independent paths through the HLO).
#[test]
fn eval_loss_consistent_with_infer_plus_bce() {
    let dir = need_artifacts!();
    let manifest = Manifest::load(&dir).unwrap();
    let engine = Engine::cpu().unwrap();
    let mut rt = ModelRuntime::load(&engine, &manifest, "tcn").unwrap();
    let (ds, split) = mk_dataset(rt.mm.window, 40_000, 11);

    let idx: Vec<usize> = split.test.iter().copied().take(rt.mm.eval.batch).collect();
    let b = rt.mm.eval.batch;
    let (x, y) = ds.gather_seq(&idx, b);
    let compiled = rt.eval_loss(x.clone(), y.clone()).unwrap() as f64;

    let probs = rt.predict(&x, b);
    let manual = bce(&probs, &y);
    assert!(
        (compiled - manual).abs() < 1e-3,
        "compiled eval {compiled:.6} vs infer+bce {manual:.6}"
    );
}

/// Training on a real labeled trace must beat the implicit LRU/RRIP
/// predictors on held-out data — the Table 1 "final loss" ordering.
#[test]
fn trained_tcn_beats_implicit_predictors() {
    let dir = need_artifacts!();
    let manifest = Manifest::load(&dir).unwrap();
    let engine = Engine::cpu().unwrap();
    let mut rt = ModelRuntime::load(&engine, &manifest, "tcn").unwrap();
    let (ds, split) = mk_dataset(rt.mm.window, 80_000, 23);
    let cfg = TrainConfig {
        epochs: 10,
        patience: 0,
        max_batches_per_epoch: 25,
        seed: 5,
        verbose_every: 0,
    };
    let res = train(&mut rt, &ds, &split, &cfg);
    let tcn_test = eval_split(&rt, &ds, &split.test);
    let lru = implicit_loss(ImplicitKind::Lru, &ds, &split.test);
    let rrip = implicit_loss(ImplicitKind::Rrip, &ds, &split.test);
    assert!(
        tcn_test < rrip && rrip < lru,
        "ordering: tcn {tcn_test:.3} < rrip {rrip:.3} < lru {lru:.3}"
    );
    assert!(res.final_train_loss < res.train_curve[0], "training must reduce loss");
}

/// Checkpoint round-trip through a *fresh* runtime instance: predictions
/// identical before/after save+load.
#[test]
fn checkpoint_restores_predictions() {
    let dir = need_artifacts!();
    let manifest = Manifest::load(&dir).unwrap();
    let engine = Engine::cpu().unwrap();
    let mut rt = ModelRuntime::load(&engine, &manifest, "dnn").unwrap();
    // Perturb weights with a couple of train steps.
    let b = rt.mm.train.batch;
    let row = rt.row_elems();
    rt.train_step(vec![0.4; b * row], vec![0.0; b]).unwrap();
    let x = vec![0.7f32; 16 * row];
    let before = rt.predict(&x, 16);
    let path = std::env::temp_dir().join("acpc_integration_ckpt.ckpt");
    rt.store.save_checkpoint(&path).unwrap();

    let mut rt2 = ModelRuntime::load(&engine, &manifest, "dnn").unwrap();
    let fresh = rt2.predict(&x, 16);
    rt2.store.load_checkpoint(&path).unwrap();
    let after = rt2.predict(&x, 16);
    assert_ne!(before, fresh, "training must have changed the model");
    assert_eq!(before, after, "checkpoint must restore predictions exactly");
    std::fs::remove_file(path).ok();
}

/// The trained TCN drives the full ACPC simulation and beats LRU — the
/// complete three-layer stack, end to end (trace → features → compiled TCN
/// via PJRT → PARM → metrics), through the public `Runner` API with an
/// injected (trained) predictor.
#[test]
fn full_stack_tcn_simulation_beats_lru() {
    let dir = need_artifacts!();
    let manifest = Manifest::load(&dir).unwrap();
    let engine = Engine::cpu().unwrap();
    let mut rt = ModelRuntime::load(&engine, &manifest, "tcn").unwrap();
    let (ds, split) = mk_dataset(rt.mm.window, 80_000, 31);
    train(
        &mut rt,
        &ds,
        &split,
        &TrainConfig { epochs: 8, patience: 0, max_batches_per_epoch: 20, seed: 2, verbose_every: 0 },
    );

    use acpc::api::{RunSpec, Runner};
    use acpc::config::PredictorKind;
    let acpc_spec = RunSpec::builder()
        .preset("smoke")
        .policy("acpc")
        .predictor(PredictorKind::Tcn)
        .accesses(120_000)
        .build()
        .unwrap();
    let acpc_run = Runner::new(acpc_spec)
        .unwrap()
        .with_predictor(PredictorBox::Model(Box::new(rt)))
        .run()
        .unwrap();

    let lru_spec = RunSpec::builder()
        .preset("smoke")
        .policy("lru")
        .predictor(PredictorKind::None)
        .accesses(120_000)
        .build()
        .unwrap();
    let lru_run = Runner::new(lru_spec).unwrap().run().unwrap();

    assert!(acpc_run.result.prediction_batches > 0);
    assert_eq!(acpc_run.predictor_effective, "tcn");
    assert!(
        acpc_run.result.report.l2_hit_rate > lru_run.result.report.l2_hit_rate,
        "tcn-acpc {:.4} vs lru {:.4}",
        acpc_run.result.report.l2_hit_rate,
        lru_run.result.report.l2_hit_rate
    );
    assert!(
        acpc_run.result.report.l2_pollution_ratio < lru_run.result.report.l2_pollution_ratio
    );
}
