//! Allocation audit for the steady-state predict path.
//!
//! The pipeline under test is the per-access prediction hot path the
//! engine's `AccessDriver` runs: feature row → `PredictionBatch::push` →
//! `PredictorBox::predict_into` → `Hierarchy::update_utility`. After one
//! warmup pass has sized every buffer and populated the bounded maps, a
//! full steady-state pass over the same working set must perform **zero**
//! heap allocations — the acceptance bar for the buffer-reuse work
//! (`PredictionBatch::clear`, `predict_into`, the staged model inference).
//! The bar is applied twice: to the heuristic predictor and to the native
//! TCN kernel (`runtime::NativeModel`, on synthetic weights), whose scratch
//! buffers must be fully sized at construction.
//!
//! This file intentionally contains a single `#[test]`: the counting
//! allocator is process-global, and a sibling test running concurrently
//! would pollute the count.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

use acpc::mem::{Hierarchy, HierarchyConfig};
use acpc::predictor::{HeuristicPredictor, PredictorBox, FEATURE_DIM};
use acpc::sim::PredictionBatch;

/// One pass of the predict pipeline over a fixed working set.
fn predict_pass(
    hier: &mut Hierarchy,
    batch: &mut PredictionBatch,
    predictor: &mut PredictorBox,
    probs: &mut Vec<f32>,
    lines: &[u64],
    feats: &[f32],
) {
    for &line in lines.iter().cycle().take(50_000) {
        let full = batch.push(line, feats);
        if full {
            predictor.predict_into(batch.x(), batch.len(), probs);
            for (&l, &p) in batch.lines().iter().zip(probs.iter()) {
                hier.update_utility(l, p);
            }
            batch.clear();
        }
    }
}

#[test]
fn steady_state_predict_path_does_not_allocate() {
    let mut hcfg = HierarchyConfig::scaled();
    hcfg.prefetcher = "none".into();
    let mut hier = Hierarchy::new(hcfg, "acpc");
    let mut batch = PredictionBatch::new(FEATURE_DIM, 256);
    let mut predictor = PredictorBox::Heuristic(HeuristicPredictor);
    let mut probs: Vec<f32> = Vec::new();

    // Fixed working set: 4096 lines, all resident in the utility map after
    // warmup (bounded well below the map's aging cap).
    let lines: Vec<u64> = (0..4096u64).map(|i| i * 3 + 1).collect();
    let mut feats = [0.0f32; FEATURE_DIM];
    feats[3] = 1.0; // weight stream
    feats[5] = 0.4; // frequency

    // Warmup: sizes the batch/probs buffers, inserts every line into the
    // bounded utility map, and lets the heuristic run end to end.
    predict_pass(&mut hier, &mut batch, &mut predictor, &mut probs, &lines, &feats);
    assert!(hier.utility_of(lines[0]).is_some(), "warmup must populate the utility cache");

    // Steady state: identical working set — the predict path must not touch
    // the allocator at all.
    let before = ALLOCS.load(Ordering::SeqCst);
    predict_pass(&mut hier, &mut batch, &mut predictor, &mut probs, &lines, &feats);
    let delta = ALLOCS.load(Ordering::SeqCst) - before;
    assert_eq!(
        delta, 0,
        "steady-state predict path performed {delta} heap allocations over 50k accesses \
         (expected 0: batch, probability and staging buffers must be reused)"
    );

    // Same bar for the native TCN kernel, on synthetic weights with the
    // production geometry (window 16, 32 channels, dilations 1/2/4): after
    // the warmup pass sizes the output buffer, the forward pass must run
    // entirely in the scratch space allocated at construction.
    let (mm, store) =
        acpc::runtime::synthetic_model("tcn", 16, FEATURE_DIM, 32, &[1, 2, 4], 0xA110C);
    let native = acpc::runtime::NativeModel::from_params(&mm, &store).unwrap();
    let window = 16;
    let mut predictor = PredictorBox::Native(native);
    let mut batch = PredictionBatch::new(window * FEATURE_DIM, 256);
    let mut probs: Vec<f32> = Vec::new();
    let feats = vec![0.25f32; window * FEATURE_DIM];

    predict_pass(&mut hier, &mut batch, &mut predictor, &mut probs, &lines, &feats);
    let before = ALLOCS.load(Ordering::SeqCst);
    predict_pass(&mut hier, &mut batch, &mut predictor, &mut probs, &lines, &feats);
    let delta = ALLOCS.load(Ordering::SeqCst) - before;
    assert_eq!(
        delta, 0,
        "native TCN steady-state predict path performed {delta} heap allocations over \
         50k accesses (expected 0: the kernel's scratch buffers are sized at construction)"
    );
}
