//! Integration tests for the scenario registry and the parallel sweep
//! runner: thread-count invariance (same seed ⇒ byte-identical reports at
//! -j 1 vs -j 8), per-scenario stream-mix smoke checks, and the full
//! acceptance grid.

use acpc::sim::{run_sweep, SweepCell, SweepConfig};
use acpc::trace::{Scenario, StreamKind, SCENARIO_NAMES};

fn small_sweep(policies: &[&str], scenarios: &[&str], threads: usize) -> Vec<SweepCell> {
    let mut cfg = SweepConfig::new(
        policies.iter().map(|s| s.to_string()).collect(),
        scenarios.iter().map(|s| s.to_string()).collect(),
    );
    cfg.accesses = 25_000;
    cfg.threads = threads;
    cfg.seed = 0xDE7E_2217;
    run_sweep(&cfg).expect("sweep")
}

/// Byte-identical serialized reports regardless of `-j`: the per-cell seed
/// derivation and the in-order result collection make thread count
/// irrelevant to everything except wall-clock.
#[test]
fn sweep_is_thread_count_invariant() {
    let policies = ["lru", "srrip", "acpc"];
    let scenarios = ["decode-heavy", "rag-embedding", "long-context"];
    let a = small_sweep(&policies, &scenarios, 1);
    let b = small_sweep(&policies, &scenarios, 8);
    assert_eq!(a.len(), b.len());
    for (ca, cb) in a.iter().zip(&b) {
        assert_eq!(ca.policy, cb.policy);
        assert_eq!(ca.scenario, cb.scenario);
        assert_eq!(ca.seed, cb.seed);
        let ja = ca.result.report.to_json().to_pretty();
        let jb = cb.result.report.to_json().to_pretty();
        assert_eq!(ja, jb, "cell {}×{} differs across -j", ca.policy, ca.scenario);
        assert_eq!(ca.result.tokens, cb.result.tokens);
        assert_eq!(ca.result.prediction_batches, cb.result.prediction_batches);
    }
}

/// Every registered scenario must actually generate the stream mix it
/// declares dominant (e.g. rag-embedding is majority Embedding traffic).
#[test]
fn scenarios_generate_their_dominant_stream_mix() {
    for sc in Scenario::all() {
        let mut w = sc.workload(11);
        let mut counts = [0usize; 5];
        let n = 60_000;
        for _ in 0..n {
            counts[w.next_access().kind as usize] += 1;
        }
        let argmax = (0..counts.len()).max_by_key(|&i| counts[i]).unwrap();
        assert_eq!(
            StreamKind::from_u8(argmax as u8),
            sc.dominant,
            "{}: mix {:?}",
            sc.name,
            StreamKind::ALL.iter().zip(&counts).collect::<Vec<_>>()
        );
        // The declared-dominant stream is a substantial share, not a
        // plurality artifact.
        assert!(
            counts[sc.dominant as usize] * 100 / n >= 30,
            "{}: dominant share too thin: {:?}",
            sc.name,
            counts
        );
    }
}

/// One `--predictor tcn` cell: with the AOT artifacts present the compiled
/// TCN runs inside the worker thread; without them the cell falls back to
/// the heuristic predictor (recorded in the cell's provenance) instead of
/// failing — either way the cell completes deterministically.
#[test]
fn sweep_predictor_tcn_cell() {
    let mut cfg = SweepConfig::new(vec!["acpc".into()], vec!["decode-heavy".into()]);
    cfg.accesses = 20_000;
    cfg.threads = 1;
    cfg.predictor = "tcn".into();
    let cells = run_sweep(&cfg).expect("tcn cell");
    assert_eq!(cells.len(), 1);
    let c = &cells[0];
    // The cell may legitimately fall back even when manifest.json exists
    // (e.g. PJRT plugin unavailable) — the contract is "tcn or recorded
    // fallback", never a panic or a silent mislabel.
    assert!(
        c.predictor == "tcn" || c.predictor == "heuristic(fallback)",
        "unexpected predictor provenance: {}",
        c.predictor
    );
    if !acpc::runtime::artifacts_available() {
        assert_eq!(c.predictor, "heuristic(fallback)");
    }
    assert_eq!(c.result.report.accesses, 20_000);
    assert!(c.result.prediction_batches > 0, "predictor must have run");
    // Deterministic across repeat runs regardless of which predictor ran.
    let again = run_sweep(&cfg).expect("tcn cell rerun");
    assert_eq!(c.result.report.l2_hit_rate, again[0].result.report.l2_hit_rate);
}

/// The speculative-decode scenario is registered end-to-end: resolvable,
/// sweepable, and dominated by verify-pass KV reads.
#[test]
fn speculative_decode_registered_and_kv_read_dominant() {
    let sc = Scenario::by_name("speculative-decode").expect("registered");
    assert_eq!(sc.dominant, StreamKind::KvRead);
    assert!(SCENARIO_NAMES.contains(&"speculative-decode"), "in the sweep default grid");
    let cells = small_sweep(&["lru", "acpc"], &["speculative-decode"], 2);
    assert_eq!(cells.len(), 2);
    for c in &cells {
        assert_eq!(c.result.report.accesses, 25_000);
        assert!(c.result.tokens > 0);
    }
}

/// rag-embedding specifically promises *majority* embedding traffic.
#[test]
fn rag_embedding_is_majority_embedding() {
    let sc = Scenario::by_name("rag-embedding").unwrap();
    let mut w = sc.workload(3);
    let n = 60_000;
    let embed = (0..n).filter(|_| w.next_access().kind == StreamKind::Embedding).count();
    assert!(embed * 2 > n, "embedding share {}/{n}", embed);
}

/// The acceptance-criteria grid: every policy×scenario cell completes and
/// produces a coherent metrics row.
#[test]
fn full_acceptance_grid_completes() {
    let policies = ["lru", "drrip", "ship", "acpc"];
    let cells = small_sweep(&policies, SCENARIO_NAMES, 4);
    assert_eq!(cells.len(), policies.len() * SCENARIO_NAMES.len());
    for c in &cells {
        assert_eq!(c.result.report.accesses, 25_000, "{}×{}", c.policy, c.scenario);
        assert!(
            c.result.report.l2_hit_rate > 0.0 && c.result.report.l2_hit_rate < 1.0,
            "{}×{}: chr {}",
            c.policy,
            c.scenario,
            c.result.report.l2_hit_rate
        );
        assert!(c.result.tokens > 0);
    }
    // Distinct scenarios must be distinguishable under the same policy.
    let lru_rates: Vec<f64> = cells
        .iter()
        .filter(|c| c.policy == "lru")
        .map(|c| c.result.report.l2_hit_rate)
        .collect();
    let spread = lru_rates.iter().cloned().fold(f64::MIN, f64::max)
        - lru_rates.iter().cloned().fold(f64::MAX, f64::min);
    assert!(spread > 0.01, "scenarios indistinguishable under lru: {lru_rates:?}");
}
