//! Integration tests for the live telemetry bus (`acpc::obs`).
//!
//! The contract under test, end to end through the public `Runner` API:
//!
//! 1. attaching a bus NEVER perturbs a run — the `RunReport` of a
//!    subscribed run is byte-identical to an unsubscribed one (single and
//!    sharded), once the two timing-only fields are normalized;
//! 2. event streams are deterministic — the same resolved spec replayed on
//!    a fresh bus produces the identical per-source event sequence;
//! 3. the ring is bounded and honest — a subscriber that never drains
//!    accounts for every published event as delivered + dropped.

use acpc::api::{AdaptSpec, PredictorFactory, RunReport, RunSpec, Runner};
use acpc::config::PredictorKind;
use acpc::obs::{TelemetryBus, TelemetryEvent};
use acpc::predictor::{PredictorBox, FEATURE_DIM};
use acpc::runtime::{synthetic_model, NativeModel, NativeWeights};
use acpc::util::json::Json;
use std::sync::Arc;

/// An adaptive spec small enough to be quick but busy enough to cross many
/// telemetry windows (and several 8192-access sample periods).
fn busy_spec(shards: usize) -> RunSpec {
    let mut spec = RunSpec::builder()
        .scenario("multi-tenant-mix")
        .policy("acpc")
        .predictor(PredictorKind::Heuristic)
        .accesses(48_000)
        .seed(42)
        .adaptive_spec(AdaptSpec {
            window_accesses: Some(2048),
            warmup_windows: Some(2),
            cooldown_windows: Some(2),
            recover_windows: Some(2),
            ..AdaptSpec::default()
        })
        .build()
        .unwrap();
    spec.shards = shards;
    spec
}

/// Report JSON with the two wall-clock-dependent fields zeroed; everything
/// else must be bit-for-bit reproducible.
fn normalized(r: &RunReport) -> String {
    let mut j = r.to_json();
    j.set("wall_secs", Json::Num(0.0));
    j.set("accesses_per_sec", Json::Num(0.0));
    j.to_pretty()
}

fn run_with_bus(spec: RunSpec) -> (RunReport, Vec<TelemetryEvent>) {
    let bus = TelemetryBus::with_capacity(1 << 16);
    let mut sub = bus.subscribe();
    let report = Runner::new(spec).unwrap().with_telemetry(bus).run().unwrap();
    let mut events = Vec::new();
    sub.drain(&mut events);
    assert_eq!(sub.dropped(), 0, "capacity chosen to hold the whole run");
    (report, events)
}

#[test]
fn subscribed_run_report_is_byte_identical_single_shard() {
    let plain = Runner::new(busy_spec(1)).unwrap().run().unwrap();
    let (subscribed, events) = run_with_bus(busy_spec(1));
    assert!(!events.is_empty(), "an adaptive run must stream events");
    assert_eq!(normalized(&plain), normalized(&subscribed));
}

#[test]
fn subscribed_run_report_is_byte_identical_sharded() {
    let plain = Runner::new(busy_spec(4)).unwrap().run().unwrap();
    let (subscribed, events) = run_with_bus(busy_spec(4));
    assert!(!events.is_empty());
    let shards: std::collections::BTreeSet<u32> =
        events.iter().map(|e| e.source.index).collect();
    assert!(shards.len() > 1, "sharded runs must stream per-shard sources, got {shards:?}");
    assert_eq!(normalized(&plain), normalized(&subscribed));
}

/// The no-perturbation contract holds on the native backend too:
/// factory-injected native predictors over *one* shared synthetic weight
/// snapshot, adaptive controller on, single-threaded and sharded.
#[test]
fn subscribed_native_backend_run_is_byte_identical() {
    let (mm, store) = synthetic_model("tcn", 16, FEATURE_DIM, 16, &[1, 2], 0xB0B5);
    let weights = Arc::new(NativeWeights::from_params(&mm, &store).unwrap());
    let factory = || -> PredictorFactory {
        let w = Arc::clone(&weights);
        Arc::new(move |_shard| PredictorBox::Native(NativeModel::from_weights(Arc::clone(&w))))
    };
    let native_spec = |shards: usize| {
        let mut spec = busy_spec(shards);
        spec.predictor = PredictorKind::Tcn;
        spec
    };
    for shards in [1usize, 4] {
        let plain = Runner::new(native_spec(shards))
            .unwrap()
            .with_predictor_factory(factory())
            .run()
            .unwrap();
        let bus = TelemetryBus::with_capacity(1 << 16);
        let mut sub = bus.subscribe();
        let subscribed = Runner::new(native_spec(shards))
            .unwrap()
            .with_predictor_factory(factory())
            .with_telemetry(bus)
            .run()
            .unwrap();
        let mut events = Vec::new();
        sub.drain(&mut events);
        assert!(!events.is_empty(), "adaptive native runs must stream events");
        assert_eq!(normalized(&plain), normalized(&subscribed), "{shards} shard(s)");
        assert!(plain.result.prediction_batches > 0, "{shards} shard(s): predictions ran");
    }
}

#[test]
fn event_sequences_are_deterministic_across_reruns() {
    // Single shard: one publisher, so even the total order must match.
    let (_, a) = run_with_bus(busy_spec(1));
    let (_, b) = run_with_bus(busy_spec(1));
    let ser = |evs: &[TelemetryEvent]| -> Vec<String> {
        evs.iter().map(|e| e.to_json().to_string()).collect()
    };
    assert!(!a.is_empty());
    assert_eq!(ser(&a), ser(&b));

    // Sharded: the ring interleaving is scheduling-dependent, but each
    // source's stream is seq-stamped by its single publisher — merged on
    // (source, seq), reruns are identical.
    let (_, mut a) = run_with_bus(busy_spec(4));
    let (_, mut b) = run_with_bus(busy_spec(4));
    a.sort_by_key(|e| (e.source, e.seq));
    b.sort_by_key(|e| (e.source, e.seq));
    assert!(!a.is_empty());
    assert_eq!(ser(&a), ser(&b));
}

#[test]
fn lagging_subscriber_drop_accounting_is_exact() {
    let bus = TelemetryBus::with_capacity(4);
    let mut sub = bus.subscribe();
    let report =
        Runner::new(busy_spec(1)).unwrap().with_telemetry(bus.clone()).run().unwrap();
    assert!(report.result.adapt_windows > 0, "precondition: the run ticks windows");

    // The subscriber slept through the whole run: a 4-slot ring can hand
    // over at most the 4 newest events; the rest must be counted, not
    // silently lost.
    let mut events = Vec::new();
    let got = sub.drain(&mut events) as u64;
    assert!(got <= 4, "bounded ring delivered {got} > capacity");
    assert!(sub.dropped() > 0, "a lagging subscriber must record drops");
    assert_eq!(got + sub.dropped(), bus.published(), "every event is delivered or counted");
    // What survives is the newest suffix, in order.
    for pair in events.windows(2) {
        assert!(pair[0].seq < pair[1].seq);
    }
}
