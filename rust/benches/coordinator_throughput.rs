//! µbench: the serving coordinator — wall-clock token throughput scaling
//! over worker counts, router imbalance, and dynamic-batcher fill, using
//! the heuristic predictor (so the bench isolates *coordination* cost from
//! model cost).

use acpc::coordinator::{serve, RouterPolicy, ServeConfig};
use acpc::predictor::{HeuristicPredictor, PredictorBox};
use acpc::util::bench::print_table;
use std::time::Duration;

fn main() {
    let smoke = matches!(std::env::var("ACPC_BENCH_SCALE").as_deref(), Ok("smoke"));
    let sessions: u64 = if smoke { 24 } else { 160 };

    let mut rows = Vec::new();
    for workers in [1usize, 2, 4] {
        for router in [RouterPolicy::RoundRobin, RouterPolicy::LeastLoaded] {
            let mut cfg = ServeConfig::quick("acpc");
            cfg.workers = workers;
            cfg.total_sessions = sessions;
            cfg.router = router;
            cfg.arrival_interval = Duration::from_micros(20);
            let rep = serve(&cfg, 1, || PredictorBox::Heuristic(HeuristicPredictor));
            rows.push(vec![
                format!("{workers}"),
                format!("{router:?}"),
                format!("{:.0}", rep.tokens_per_sec_wall),
                format!("{:.1}", rep.l2_hit_rate * 100.0),
                format!("{:.1}", rep.session_latency_ms_p50),
                format!("{:.1}", rep.session_latency_ms_p95),
                format!("{:.1}", rep.mean_batch_fill),
                format!("{}", rep.router_imbalance_max),
            ]);
        }
    }
    print_table(
        "Coordinator scaling (heuristic predictor)",
        &["workers", "router", "tok/s", "CHR %", "p50 ms", "p95 ms", "batch fill", "imbalance"],
        &rows,
    );
}
