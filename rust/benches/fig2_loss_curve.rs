//! Bench: regenerate the paper's **Figure 2** (training-loss curve of the
//! ACPC Temporal CNN) — rust-driven training of the compiled Adam step.
//!
//! Paper shape: loss starts ≈0.8, falls below ≈0.3 by ~epoch 20, converges
//! ≈0.21 by epochs 60–80, smooth and monotone-ish. We print the measured
//! curve (ASCII), the shape checkpoints, and write `reports/fig2.json`.
//!
//! Scale via env: `ACPC_BENCH_SCALE=full|smoke`.

use acpc::predictor::{Dataset, GeometryHints, ModelRuntime};
use acpc::runtime::{Engine, Manifest};
use acpc::trace::{GeneratorConfig, ModelProfile, TraceGenerator};
use acpc::training::{train, TrainConfig};
use acpc::util::json::Json;

fn main() {
    let Some(dir) = acpc::runtime::artifacts_dir() else {
        acpc::log_warn!("fig2 bench: artifacts/ missing — run `make artifacts` first");
        std::process::exit(0);
    };
    let smoke = matches!(std::env::var("ACPC_BENCH_SCALE").as_deref(), Ok("smoke"));
    let (accesses, epochs, max_batches) =
        if smoke { (150_000, 8, 12) } else { (1_200_000, 80, 120) };

    let manifest = Manifest::load(&dir).unwrap();
    let engine = Engine::cpu().unwrap();
    let mut rt = ModelRuntime::load(&engine, &manifest, "tcn").unwrap();

    let seed = 0xF162_2025;
    let gcfg = GeneratorConfig::new(ModelProfile::gpt3ish(), seed);
    let geom = GeometryHints::from_generator(&gcfg);
    println!("generating training trace ({accesses} accesses) ...");
    let trace = TraceGenerator::new(gcfg).generate(accesses);
    let ds = Dataset::build(&trace, rt.mm.window, geom, 4096, 6);
    let split = ds.split(seed);
    println!("dataset n={} positive_rate={:.3}", ds.n, ds.positive_rate());

    let t0 = std::time::Instant::now();
    let res = train(
        &mut rt,
        &ds,
        &split,
        &TrainConfig {
            epochs,
            patience: if smoke { 0 } else { 15 },
            max_batches_per_epoch: max_batches,
            seed,
            verbose_every: 10,
        },
    );
    let wall = t0.elapsed().as_secs_f64();

    println!("\n=== Figure 2 (reproduced): TCN training loss ===");
    println!("{}", acpc::cli::commands::ascii_plot(&res.train_curve, 70, 16));
    let e20 = res.train_curve.get(19).copied().unwrap_or(f64::NAN);
    println!(
        "shape: start={:.3} (paper ≈0.8) | epoch20={:.3} (paper ≈0.3) | final={:.3} (paper ≈0.21)",
        res.train_curve.first().copied().unwrap_or(f64::NAN),
        e20,
        res.final_train_loss
    );
    println!(
        "epochs={} early_stop={} stability={} val_final={:.3} wall={:.1}s",
        res.epochs_run,
        res.stopped_early,
        res.stability(),
        res.final_val_loss,
        wall
    );

    std::fs::create_dir_all("reports").ok();
    let j = Json::from_pairs(vec![
        ("train_curve", Json::array_f64(&res.train_curve)),
        ("val_curve", Json::array_f64(&res.val_curve)),
        ("final_train_loss", Json::Num(res.final_train_loss)),
        ("stability", Json::Str(res.stability())),
        ("epochs", Json::Num(res.epochs_run as f64)),
    ]);
    std::fs::write("reports/fig2.json", j.to_pretty()).unwrap();
    println!("report: reports/fig2.json");
}
