//! Ablation B (DESIGN.md §5): dilation schedule of the TCN — the paper's
//! [1,2,4] (receptive field 15) vs a flat [1,1,1] stack (RF 7) vs a
//! two-layer [1,2] variant (RF 7, fewer params). Each variant is a separate
//! AOT artifact, trained identically here; we report the converged BCE.
//!
//! `ACPC_BENCH_SCALE=smoke` shrinks the trace/epochs.

use acpc::predictor::{Dataset, GeometryHints, ModelRuntime};
use acpc::runtime::{Engine, Manifest};
use acpc::trace::{GeneratorConfig, ModelProfile, TraceGenerator};
use acpc::training::{eval_split, train, TrainConfig};
use acpc::util::bench::print_table;

fn main() {
    let Some(dir) = acpc::runtime::artifacts_dir() else {
        acpc::log_warn!("ablation_dilation: artifacts/ missing — run `make artifacts`");
        std::process::exit(0);
    };
    let smoke = matches!(std::env::var("ACPC_BENCH_SCALE").as_deref(), Ok("smoke"));
    let (accesses, epochs, max_batches) = if smoke { (150_000, 6, 10) } else { (800_000, 40, 80) };

    let manifest = Manifest::load(&dir).unwrap();
    let engine = Engine::cpu().unwrap();

    let seed = 0xD11A;
    let gcfg = GeneratorConfig::new(ModelProfile::gpt3ish(), seed);
    let geom = GeometryHints::from_generator(&gcfg);
    println!("generating training trace ({accesses} accesses) ...");
    let trace = TraceGenerator::new(gcfg).generate(accesses);

    let mut rows = Vec::new();
    for name in ["tcn", "tcn_flat", "tcn_short"] {
        let mut rt = ModelRuntime::load(&engine, &manifest, name).unwrap();
        let ds = Dataset::build(&trace, rt.mm.window, geom, 4096, 6);
        let split = ds.split(seed);
        let res = train(
            &mut rt,
            &ds,
            &split,
            &TrainConfig {
                epochs,
                patience: 0,
                max_batches_per_epoch: max_batches,
                seed,
                verbose_every: 0,
            },
        );
        let test = eval_split(&rt, &ds, &split.test);
        println!(
            "{name}: dilations {:?} → train {:.3} val {:.3} test {:.3} ({})",
            rt.mm.dilations, res.final_train_loss, res.final_val_loss, test, res.stability()
        );
        rows.push(vec![
            name.to_string(),
            format!("{:?}", rt.mm.dilations),
            format!("{:.3}", res.final_train_loss),
            format!("{:.3}", res.final_val_loss),
            format!("{:.3}", test),
            res.stability(),
        ]);
    }
    print_table(
        "Ablation B — TCN dilation schedule",
        &["model", "dilations", "train BCE", "val BCE", "test BCE", "stability"],
        &rows,
    );
}
