//! Bench: the parallel policy×scenario sweep — aggregate simulated
//! accesses/second as `-j` scales, over the full scenario registry.
//!
//! `ACPC_BENCH_SCALE=smoke` shrinks the per-cell trace.

use acpc::sim::sweep::{render_cells, run_sweep, SweepConfig};
use acpc::util::pool::default_threads;

fn main() {
    let smoke = matches!(std::env::var("ACPC_BENCH_SCALE").as_deref(), Ok("smoke"));
    let accesses = if smoke { 40_000 } else { 400_000 };

    for threads in [1, 2, default_threads()] {
        let mut cfg = SweepConfig::default_grid();
        cfg.accesses = accesses;
        cfg.threads = threads;
        let t0 = std::time::Instant::now();
        let cells = run_sweep(&cfg).expect("sweep");
        let wall = t0.elapsed().as_secs_f64();
        let total: u64 = cells.iter().map(|c| c.result.report.accesses).sum();
        println!(
            "-j {:>2}: {} cells, {:.2}s wall, {:.2}M acc/s aggregate",
            threads,
            cells.len(),
            wall,
            total as f64 / wall / 1e6
        );
        if threads == default_threads() {
            println!("\n{}", render_cells(&cells));
        }
    }
}
