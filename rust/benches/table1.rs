//! Bench: regenerate the paper's **Table 1** (Comparative Performance of
//! Different Models) — CHR / PPR / MPR / TGT / final loss / stability for
//! LRU, static RRIP, ML-Predict (DNN) and Temporal CNN (ACPC).
//!
//! Scale via env: `ACPC_BENCH_SCALE=full|smoke` (default full).
//! Output: the paper-format table + headline deltas + per-run reports,
//! also written to `reports/table1.json`.

use acpc::metrics::render_table1;
use acpc::sim::{run_table1, Table1Scale};
use acpc::util::json::Json;

fn main() {
    let scale = match std::env::var("ACPC_BENCH_SCALE").as_deref() {
        Ok("smoke") => Table1Scale::smoke(),
        _ => Table1Scale::full(),
    };
    if acpc::runtime::artifacts_dir().is_none() {
        acpc::log_warn!("table1 bench: artifacts/ missing — run `make artifacts` first");
        std::process::exit(0);
    }
    let t0 = std::time::Instant::now();
    let out = run_table1(&scale).expect("table1 pipeline");

    println!("\n=== Table 1 (reproduced; paper values below) ===");
    println!("{}", render_table1(&out.rows));
    println!("paper:   LRU 71.4/18.7/0.0/187/0.84 | RRIP 76.8/14.2/7.9/195/0.69");
    println!("paper:   DNN 82.3/10.8/15.5/214/0.47 | TCN 89.6/6.3/24.8/248/0.21");
    println!("\n{}", out.headline_deltas());
    println!("\nheld-out BCE: tcn={:.3} dnn={:.3}", out.tcn_test_loss, out.dnn_test_loss);
    for r in &out.reports {
        println!("{}", r.summary());
    }
    println!("\nwall time: {:.1}s", t0.elapsed().as_secs_f64());

    std::fs::create_dir_all("reports").ok();
    let rows: Vec<Json> = out
        .rows
        .iter()
        .map(|r| {
            Json::from_pairs(vec![
                ("model", Json::Str(r.model.clone())),
                ("chr", Json::Num(r.chr)),
                ("ppr", Json::Num(r.ppr)),
                ("mpr", Json::Num(r.mpr)),
                ("tgt", Json::Num(r.tgt)),
                ("final_loss", Json::Num(r.final_loss)),
                ("stability", Json::Str(r.stability.clone())),
            ])
        })
        .collect();
    let j = Json::from_pairs(vec![
        ("table", Json::Arr(rows)),
        ("tcn_curve", Json::array_f64(&out.tcn_curve)),
        ("dnn_curve", Json::array_f64(&out.dnn_curve)),
    ]);
    std::fs::write("reports/table1.json", j.to_pretty()).expect("write report");
    println!("report: reports/table1.json");
}
