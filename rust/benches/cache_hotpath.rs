//! µbench: the simulator hot path — hierarchy accesses/second per policy,
//! plus the raw trace-generation rate. This is the L3 perf target from
//! DESIGN.md §8 (≥10M LRU accesses/s single-thread) and feeds
//! EXPERIMENTS.md §Perf.

use acpc::mem::{Hierarchy, HierarchyConfig};
use acpc::policy::AccessMeta;
use acpc::trace::{GeneratorConfig, ModelProfile, TraceGenerator};
use acpc::util::bench::{black_box, Bench};

fn main() {
    let n = 1_000_000usize;
    let gcfg = GeneratorConfig::new(ModelProfile::gpt3ish(), 42);

    // Raw generator rate (upper bound for streaming mode).
    let bench = Bench::new(1, 5).throughput(n as u64);
    bench.run("trace_generator", || {
        let mut gen = TraceGenerator::new(gcfg.clone());
        for _ in 0..n {
            black_box(gen.next_access());
        }
    });

    // Pre-materialized trace → pure cache-simulator rate per policy.
    let trace = TraceGenerator::new(gcfg.clone()).generate(n);
    for policy in ["lru", "plru", "srrip", "drrip", "dip", "ship", "acpc", "mlpredict"] {
        let mut hcfg = HierarchyConfig::scaled();
        hcfg.prefetcher = "composite".into();
        bench.run(&format!("hierarchy[{policy}]"), || {
            let mut h = Hierarchy::new(hcfg.clone(), policy);
            for a in &trace {
                let meta = AccessMeta::demand(a.line(), a.pc, a.kind);
                black_box(h.access(a, &meta));
            }
        });
    }

    // No-prefetcher variant isolates prefetch-machinery cost.
    let mut hcfg = HierarchyConfig::scaled();
    hcfg.prefetcher = "none".into();
    bench.run("hierarchy[lru,no-prefetch]", || {
        let mut h = Hierarchy::new(hcfg.clone(), "lru");
        for a in &trace {
            let meta = AccessMeta::demand(a.line(), a.pc, a.kind);
            black_box(h.access(a, &meta));
        }
    });
}
