//! µbench: the simulator hot path — engine accesses/second per policy,
//! plus the raw trace-generation rate. This is the L3 perf target from
//! DESIGN.md §8 (≥10M LRU accesses/s single-thread) and feeds
//! EXPERIMENTS.md §Perf.
//!
//! Accesses are driven through the shared `sim::Engine` (the same loop the
//! CLI, sweep runner and coordinator use), so the numbers here are the real
//! end-to-end per-access cost, not a bench-only replica of it.
//!
//! `ACPC_BENCH_SCALE=smoke` shrinks the trace for CI; results land in
//! the `BENCH_sim.json` history (schema `acpc-bench-v2`) for the
//! machine-readable perf trajectory.

use acpc::mem::HierarchyConfig;
use acpc::predictor::GeometryHints;
use acpc::sim::Engine;
use acpc::trace::{GeneratorConfig, ModelProfile, TraceGenerator};
use acpc::util::bench::{bench_scale, black_box, Bench, BenchJson};

fn main() {
    let smoke = bench_scale() == "smoke";
    let n = if smoke { 120_000 } else { 1_000_000 };
    let iters = if smoke { 2 } else { 5 };
    let gcfg = GeneratorConfig::new(ModelProfile::gpt3ish(), 42);
    let geom = GeometryHints::from_generator(&gcfg);
    let mut sink = BenchJson::new("cache_hotpath");

    // Raw generator rate (upper bound for streaming mode).
    let bench = Bench::new(1, iters).throughput(n as u64);
    sink.push(&bench.run("trace_generator", || {
        let mut gen = TraceGenerator::new(gcfg.clone());
        for _ in 0..n {
            black_box(gen.next_access());
        }
    }));

    // Pre-materialized trace → pure engine rate per policy.
    let trace = TraceGenerator::new(gcfg.clone()).generate(n);
    for policy in ["lru", "plru", "srrip", "drrip", "dip", "ship", "acpc", "mlpredict"] {
        let mut hcfg = HierarchyConfig::scaled();
        hcfg.prefetcher = "composite".into();
        sink.push(&bench.run(&format!("engine[{policy}]"), || {
            let mut eng = Engine::new(hcfg.clone(), policy, geom, 0);
            for a in &trace {
                black_box(eng.step(a, None));
            }
        }));
    }

    // Feature extraction enabled (window 1) isolates the predictor-feed cost.
    let mut hcfg = HierarchyConfig::scaled();
    hcfg.prefetcher = "composite".into();
    sink.push(&bench.run("engine[acpc,features]", || {
        let mut eng = Engine::new(hcfg.clone(), "acpc", geom, 1);
        for a in &trace {
            black_box(eng.step(a, None));
        }
    }));

    // No-prefetcher variant isolates prefetch-machinery cost.
    let mut hcfg = HierarchyConfig::scaled();
    hcfg.prefetcher = "none".into();
    sink.push(&bench.run("engine[lru,no-prefetch]", || {
        let mut eng = Engine::new(hcfg.clone(), "lru", geom, 0);
        for a in &trace {
            black_box(eng.step(a, None));
        }
    }));

    // O(1) residency metrics: occupancy/useful_fraction used to scan every
    // line; they are now incremental counters. Hammer them at EMU-sampling
    // frequency to keep the regression visible in the trajectory.
    let mut hcfg = HierarchyConfig::scaled();
    hcfg.prefetcher = "none".into();
    let mut eng = Engine::new(hcfg.clone(), "lru", geom, 0);
    for a in trace.iter().take(100_000.min(n)) {
        eng.step(a, None);
    }
    let probes = if smoke { 100_000u64 } else { 1_000_000u64 };
    let pb = Bench::new(1, iters).throughput(probes);
    sink.push(&pb.run("l2_occupancy+useful_fraction", || {
        let mut acc = 0.0f64;
        for _ in 0..probes {
            acc += black_box(eng.hier.l2.occupancy());
            let f = eng.hier.l2.useful_fraction();
            if f.is_finite() {
                acc += f;
            }
        }
        black_box(acc);
    }));

    match sink.write() {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => acpc::log_error!("BENCH_sim.json write failed: {e}"),
    }
}
