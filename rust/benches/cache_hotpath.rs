//! µbench: the simulator hot path — engine accesses/second per policy,
//! plus the raw trace-generation rate. This is the L3 perf target from
//! DESIGN.md §8 (≥10M LRU accesses/s single-thread) and feeds
//! EXPERIMENTS.md §Perf.
//!
//! Accesses are driven through the shared `sim::Engine` (the same loop the
//! CLI, sweep runner and coordinator use), so the numbers here are the real
//! end-to-end per-access cost, not a bench-only replica of it.

use acpc::mem::HierarchyConfig;
use acpc::predictor::GeometryHints;
use acpc::sim::Engine;
use acpc::trace::{GeneratorConfig, ModelProfile, TraceGenerator};
use acpc::util::bench::{black_box, Bench};

fn main() {
    let n = 1_000_000usize;
    let gcfg = GeneratorConfig::new(ModelProfile::gpt3ish(), 42);
    let geom = GeometryHints::from_generator(&gcfg);

    // Raw generator rate (upper bound for streaming mode).
    let bench = Bench::new(1, 5).throughput(n as u64);
    bench.run("trace_generator", || {
        let mut gen = TraceGenerator::new(gcfg.clone());
        for _ in 0..n {
            black_box(gen.next_access());
        }
    });

    // Pre-materialized trace → pure engine rate per policy.
    let trace = TraceGenerator::new(gcfg.clone()).generate(n);
    for policy in ["lru", "plru", "srrip", "drrip", "dip", "ship", "acpc", "mlpredict"] {
        let mut hcfg = HierarchyConfig::scaled();
        hcfg.prefetcher = "composite".into();
        bench.run(&format!("engine[{policy}]"), || {
            let mut eng = Engine::new(hcfg.clone(), policy, geom, 0);
            for a in &trace {
                black_box(eng.step(a, None));
            }
        });
    }

    // Feature extraction enabled (window 1) isolates the predictor-feed cost.
    let mut hcfg = HierarchyConfig::scaled();
    hcfg.prefetcher = "composite".into();
    bench.run("engine[acpc,features]", || {
        let mut eng = Engine::new(hcfg.clone(), "acpc", geom, 1);
        for a in &trace {
            black_box(eng.step(a, None));
        }
    });

    // No-prefetcher variant isolates prefetch-machinery cost.
    let mut hcfg = HierarchyConfig::scaled();
    hcfg.prefetcher = "none".into();
    bench.run("engine[lru,no-prefetch]", || {
        let mut eng = Engine::new(hcfg.clone(), "lru", geom, 0);
        for a in &trace {
            black_box(eng.step(a, None));
        }
    });
}
