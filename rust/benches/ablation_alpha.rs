//! Ablation A (DESIGN.md §5): sweep the PARM balance coefficient α (eq. 3)
//! with the heuristic predictor held fixed — how much does blending
//! prediction (α→1) vs frequency (α→0) matter?
//!
//! Runs the sweep in parallel over the thread pool; each α point is one
//! `RunSpec` executed through the unified `Runner`.
//! `ACPC_BENCH_SCALE=smoke` shrinks the per-point trace.

use acpc::api::{RunReport, RunSpec, Runner};
use acpc::config::PredictorKind;
use acpc::util::bench::print_table;
use acpc::util::pool::{default_threads, run_parallel};

fn main() {
    let smoke = matches!(std::env::var("ACPC_BENCH_SCALE").as_deref(), Ok("smoke"));
    let accesses = if smoke { 150_000 } else { 1_000_000 };
    let alphas = [0.0, 0.25, 0.5, 0.7, 0.9, 1.0];

    let jobs: Vec<_> = alphas
        .iter()
        .map(|&alpha| {
            move || -> (f64, RunReport) {
                let spec = RunSpec::builder()
                    .policy(&format!("acpc@{alpha}"))
                    .predictor(PredictorKind::Heuristic)
                    .accesses(accesses)
                    .build()
                    .expect("valid alpha spec");
                (alpha, Runner::new(spec).expect("resolve").run().expect("run"))
            }
        })
        .collect();
    let results = run_parallel(default_threads(), jobs);

    let rows: Vec<Vec<String>> = results
        .iter()
        .map(|(alpha, r)| {
            vec![
                format!("{alpha:.2}"),
                format!("{:.1}", r.result.report.l2_hit_rate * 100.0),
                format!("{:.2}", r.result.report.l2_pollution_ratio * 100.0),
                format!("{:.2}", r.result.report.amat),
                format!("{:.2}", r.result.emu),
            ]
        })
        .collect();
    print_table(
        "Ablation A — PARM α sweep (eq. 3), heuristic predictor",
        &["alpha", "CHR %", "PPR %", "AMAT", "EMU"],
        &rows,
    );

    let chr = |i: usize| results[i].1.result.report.l2_hit_rate;
    println!(
        "\nmid-range best CHR {:.3} vs extremes (α=0: {:.3}, α=1: {:.3})",
        chr(2).max(chr(3)).max(chr(4)),
        chr(0),
        chr(5)
    );
}
