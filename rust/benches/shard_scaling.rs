//! Bench: set-sharded single-cell throughput — accesses/second for one
//! decode-heavy simulation cell as `--shards` scales, plus the exactness
//! check (aggregate metrics identical across shard counts for a set-local
//! configuration). Every run is a `RunSpec` executed through the unified
//! `Runner` — the same code path as the CLI and the library.
//!
//! `ACPC_BENCH_SCALE=smoke` shrinks the trace. Results (including the
//! scaling curve and per-shard-count speedups) merge into `BENCH_sim.json`
//! for the machine-readable perf trajectory.

use acpc::api::{RunReport, RunSpec, Runner};
use acpc::config::PredictorKind;
use acpc::util::bench::{bench_scale, Bench, BenchJson};
use acpc::util::json::Json;
use acpc::util::pool::default_threads;

fn cell_spec(
    policy: &str,
    kind: PredictorKind,
    accesses: usize,
    prefetcher: &str,
    shards: usize,
) -> RunSpec {
    RunSpec::builder()
        .scenario("decode-heavy")
        .policy(policy)
        .predictor(kind)
        .accesses(accesses)
        .seed(0x5CA1E)
        .prefetcher(prefetcher)
        .shards(shards)
        .build()
        .expect("valid bench spec")
}

fn run(spec: RunSpec) -> RunReport {
    Runner::new(spec).expect("resolve").run().expect("sharded run")
}

fn main() {
    let smoke = bench_scale() == "smoke";
    let accesses = if smoke { 200_000 } else { 4_000_000 };
    let iters = if smoke { 1 } else { 3 };
    let mut sink = BenchJson::new("shard_scaling");

    // Shard counts to sweep: powers of two up to the machine (the scaled
    // hierarchy supports up to 32).
    let max_shards = (default_threads() + 1).next_power_of_two().min(32).max(8);
    let mut shard_counts = vec![1usize];
    while *shard_counts.last().unwrap() < max_shards {
        shard_counts.push(shard_counts.last().unwrap() * 2);
    }

    println!("shard scaling: decode-heavy, {accesses} accesses/run, shards {shard_counts:?}\n");
    let bench = Bench::new(if smoke { 0 } else { 1 }, iters).throughput(accesses as u64);

    // Throughput curve on the realistic configuration (lru + composite
    // prefetcher, per-shard prefetch engines).
    let mut curve: Vec<f64> = Vec::new();
    for &shards in &shard_counts {
        let r = bench.run(&format!("decode-heavy[lru,composite] shards={shards}"), || {
            let out = run(cell_spec("lru", PredictorKind::None, accesses, "composite", shards));
            assert_eq!(out.result.report.accesses, accesses as u64);
        });
        curve.push(r.throughput.unwrap_or(0.0));
        sink.push(&r);
    }
    let speedups: Vec<f64> = curve.iter().map(|&t| t / curve[0].max(1e-9)).collect();
    println!("\nspeedup vs 1 shard: {speedups:?}");

    // ACPC + heuristic predictor: the full prediction pipeline sharded.
    let mut pred_curve: Vec<f64> = Vec::new();
    for &shards in &shard_counts {
        let r = bench.run(&format!("decode-heavy[acpc,heuristic] shards={shards}"), || {
            let out =
                run(cell_spec("acpc", PredictorKind::Heuristic, accesses, "composite", shards));
            assert_eq!(out.result.report.accesses, accesses as u64);
        });
        pred_curve.push(r.throughput.unwrap_or(0.0));
        sink.push(&r);
    }

    // Exactness: with a set-local configuration (prefetcher off, lru at L2,
    // srrip at L3 — the default DRRIP LLC has a global PSEL/RNG) every
    // counter-derived aggregate must be bit-identical for every shard count
    // (EMU is excluded: its sampling instants are shard-local).
    let exact_accesses = accesses.min(400_000);
    let exact_spec = |shards: usize| {
        RunSpec::builder()
            .scenario("decode-heavy")
            .policy("lru")
            .predictor(PredictorKind::None)
            .accesses(exact_accesses)
            .seed(0x5CA1E)
            .prefetcher("none")
            .l3_policy("srrip")
            .shards(shards)
            .build()
            .expect("valid exactness spec")
    };
    let reference = run(exact_spec(1));
    let rref = &reference.result.report;
    for &shards in &shard_counts[1..] {
        let run = run(exact_spec(shards));
        let r = &run.result.report;
        assert_eq!(r.accesses, rref.accesses, "{shards} shards: accesses");
        assert_eq!(r.l2_hit_rate.to_bits(), rref.l2_hit_rate.to_bits(), "{shards}: hit rate");
        assert_eq!(
            r.l2_pollution_ratio.to_bits(),
            rref.l2_pollution_ratio.to_bits(),
            "{shards}: pollution"
        );
        assert_eq!(r.amat.to_bits(), rref.amat.to_bits(), "{shards} shards: amat");
        assert_eq!(r.l2_miss_cycles, rref.l2_miss_cycles, "{shards} shards: miss cycles");
        assert_eq!(r.total_latency, rref.total_latency, "{shards} shards: latency");
    }
    println!("exactness: hit-rate/pollution/AMAT identical across shards {shard_counts:?} ✓");

    sink.set(
        "shards",
        Json::Arr(shard_counts.iter().map(|&s| Json::Num(s as f64)).collect()),
    );
    sink.set("accesses_per_sec", Json::array_f64(&curve));
    sink.set("accesses_per_sec_acpc_heuristic", Json::array_f64(&pred_curve));
    sink.set("speedup_vs_1_shard", Json::array_f64(&speedups));
    sink.set("exactness_checked", Json::Bool(true));
    match sink.write() {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => acpc::log_error!("BENCH_sim.json write failed: {e}"),
    }
}
