//! Bench: set-sharded single-cell throughput — accesses/second for one
//! decode-heavy simulation cell as `--shards` scales, plus the exactness
//! check (aggregate metrics identical across shard counts for a set-local
//! configuration).
//!
//! `ACPC_BENCH_SCALE=smoke` shrinks the trace. Results (including the
//! scaling curve and per-shard-count speedups) merge into `BENCH_sim.json`
//! for the machine-readable perf trajectory.

use acpc::config::{ExperimentConfig, PredictorKind};
use acpc::predictor::{HeuristicPredictor, PredictorBox};
use acpc::sim::run_workload_sharded;
use acpc::util::bench::{bench_scale, Bench, BenchJson};
use acpc::util::json::Json;
use acpc::util::pool::default_threads;

fn cell_cfg(policy: &str, accesses: usize, prefetcher: &str) -> ExperimentConfig {
    let mut cfg =
        ExperimentConfig::for_scenario("decode-heavy", policy, PredictorKind::None, 0x5CA1E)
            .expect("decode-heavy registered");
    cfg.accesses = accesses;
    cfg.hierarchy.prefetcher = prefetcher.into();
    cfg
}

fn mk_none(_shard: usize) -> PredictorBox {
    PredictorBox::None
}

fn mk_heuristic(_shard: usize) -> PredictorBox {
    PredictorBox::Heuristic(HeuristicPredictor)
}

fn main() {
    let smoke = bench_scale() == "smoke";
    let accesses = if smoke { 200_000 } else { 4_000_000 };
    let iters = if smoke { 1 } else { 3 };
    let mut sink = BenchJson::new("shard_scaling");

    // Shard counts to sweep: powers of two up to the machine (the scaled
    // hierarchy supports up to 32).
    let max_shards = (default_threads() + 1).next_power_of_two().min(32).max(8);
    let mut shard_counts = vec![1usize];
    while *shard_counts.last().unwrap() < max_shards {
        shard_counts.push(shard_counts.last().unwrap() * 2);
    }

    println!("shard scaling: decode-heavy, {accesses} accesses/run, shards {shard_counts:?}\n");
    let bench = Bench::new(if smoke { 0 } else { 1 }, iters).throughput(accesses as u64);

    // Throughput curve on the realistic configuration (lru + composite
    // prefetcher, per-shard prefetch engines).
    let mut curve: Vec<f64> = Vec::new();
    for &shards in &shard_counts {
        let cfg = cell_cfg("lru", accesses, "composite");
        let r = bench.run(&format!("decode-heavy[lru,composite] shards={shards}"), || {
            let mut w = cfg.workload();
            let out = run_workload_sharded(&cfg, w.as_mut(), shards, &mk_none, None)
                .expect("sharded run");
            assert_eq!(out.result.report.accesses, accesses as u64);
        });
        curve.push(r.throughput.unwrap_or(0.0));
        sink.push(&r);
    }
    let speedups: Vec<f64> = curve.iter().map(|&t| t / curve[0].max(1e-9)).collect();
    println!("\nspeedup vs 1 shard: {speedups:?}");

    // ACPC + heuristic predictor: the full prediction pipeline sharded.
    let mut pred_curve: Vec<f64> = Vec::new();
    for &shards in &shard_counts {
        let cfg = {
            let mut c = cell_cfg("acpc", accesses, "composite");
            c.predictor = PredictorKind::Heuristic;
            c
        };
        let r = bench.run(&format!("decode-heavy[acpc,heuristic] shards={shards}"), || {
            let mut w = cfg.workload();
            let out = run_workload_sharded(&cfg, w.as_mut(), shards, &mk_heuristic, None)
                .expect("sharded run");
            assert_eq!(out.result.report.accesses, accesses as u64);
        });
        pred_curve.push(r.throughput.unwrap_or(0.0));
        sink.push(&r);
    }

    // Exactness: with a set-local configuration (prefetcher off, lru at L2,
    // srrip at L3 — the default DRRIP LLC has a global PSEL/RNG) every
    // counter-derived aggregate must be bit-identical for every shard count
    // (EMU is excluded: its sampling instants are shard-local).
    let exact_accesses = accesses.min(400_000);
    let mut cfg = cell_cfg("lru", exact_accesses, "none");
    cfg.hierarchy.l3_policy = "srrip".into();
    let reference = {
        let mut w = cfg.workload();
        run_workload_sharded(&cfg, w.as_mut(), 1, &mk_none, None).unwrap()
    };
    let rref = &reference.result.report;
    for &shards in &shard_counts[1..] {
        let mut w = cfg.workload();
        let run = run_workload_sharded(&cfg, w.as_mut(), shards, &mk_none, None).unwrap();
        let r = &run.result.report;
        assert_eq!(r.accesses, rref.accesses, "{shards} shards: accesses");
        assert_eq!(r.l2_hit_rate.to_bits(), rref.l2_hit_rate.to_bits(), "{shards}: hit rate");
        assert_eq!(
            r.l2_pollution_ratio.to_bits(),
            rref.l2_pollution_ratio.to_bits(),
            "{shards}: pollution"
        );
        assert_eq!(r.amat.to_bits(), rref.amat.to_bits(), "{shards} shards: amat");
        assert_eq!(r.l2_miss_cycles, rref.l2_miss_cycles, "{shards} shards: miss cycles");
        assert_eq!(r.total_latency, rref.total_latency, "{shards} shards: latency");
    }
    println!("exactness: hit-rate/pollution/AMAT identical across shards {shard_counts:?} ✓");

    sink.set(
        "shards",
        Json::Arr(shard_counts.iter().map(|&s| Json::Num(s as f64)).collect()),
    );
    sink.set("accesses_per_sec", Json::array_f64(&curve));
    sink.set("accesses_per_sec_acpc_heuristic", Json::array_f64(&pred_curve));
    sink.set("speedup_vs_1_shard", Json::array_f64(&speedups));
    sink.set("exactness_checked", Json::Bool(true));
    match sink.write() {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("BENCH_sim.json write failed: {e}"),
    }
}
