//! µbench: predictor-service latency/throughput — per-batch PJRT dispatch
//! for the compiled TCN/DNN at their fixed AOT batch sizes, plus the
//! feature-extraction rate feeding them. Targets EXPERIMENTS.md §Perf
//! ("predictor amortized to <10% of end-to-end sim time").

use acpc::predictor::{FeatureExtractor, GeometryHints, ModelRuntime, ReusePredictor};
use acpc::runtime::{Engine, Manifest};
use acpc::trace::{GeneratorConfig, ModelProfile, TraceGenerator};
use acpc::util::bench::{black_box, Bench};

fn main() {
    let Some(dir) = acpc::runtime::artifacts_dir() else {
        acpc::log_warn!("predictor_latency: artifacts/ missing — run `make artifacts`");
        std::process::exit(0);
    };
    let manifest = Manifest::load(&dir).unwrap();
    let engine = Engine::cpu().unwrap();

    // Feature extraction rate.
    let gcfg = GeneratorConfig::new(ModelProfile::gpt3ish(), 3);
    let geom = GeometryHints::from_generator(&gcfg);
    let trace = TraceGenerator::new(gcfg).generate(200_000);
    let window = manifest.model("tcn").unwrap().window;
    let bench = Bench::new(1, 5).throughput(trace.len() as u64);
    bench.run("feature_extractor.push", || {
        let mut fx = FeatureExtractor::new(window, geom);
        let mut seq = vec![0.0f32; window * acpc::predictor::FEATURE_DIM];
        for a in &trace {
            fx.push(a, &mut seq);
            black_box(seq[0]);
        }
    });

    // Model inference at the AOT batch size.
    for name in ["tcn", "dnn"] {
        let mut rt = ModelRuntime::load(&engine, &manifest, name).unwrap();
        let b = rt.infer_batch;
        let row = rt.row_elems();
        let x = vec![0.3f32; b * row];
        let bench = Bench::new(2, 10).throughput(b as u64);
        bench.run(&format!("{name}.predict[b={b}]"), || {
            black_box(rt.predict(&x, b));
        });
    }

    // Train step latency (online-learning budget).
    for name in ["tcn", "dnn"] {
        let mut rt = ModelRuntime::load(&engine, &manifest, name).unwrap();
        let b = rt.mm.train.batch;
        let row = rt.row_elems();
        let x = vec![0.3f32; b * row];
        let y = vec![1.0f32; b];
        let bench = Bench::new(1, 5).throughput(b as u64);
        bench.run(&format!("{name}.train_step[b={b}]"), || {
            black_box(rt.train_step(x.clone(), y.clone()).unwrap());
        });
    }
}
