//! µbench: predictor inference latency — the native Rust kernel against
//! per-batch PJRT dispatch, plus the feature-extraction rate feeding them.
//! Targets EXPERIMENTS.md §Perf ("predictor amortized to <10% of
//! end-to-end sim time").
//!
//! The native section needs no artifacts (synthetic weights at the
//! production TCN geometry) and always records a `native_tcn_infer` case
//! into the BENCH_sim.json perf trajectory, so `acpc diff --bench` gates
//! the kernel on every CI run. The PJRT comparison — the per-row speedup
//! the native kernel claims — additionally runs when `artifacts/` is
//! present and prints the ratio for each manifest model.

use acpc::predictor::{
    Backend, FeatureExtractor, GeometryHints, ModelRuntime, ReusePredictor, FEATURE_DIM,
};
use acpc::runtime::{synthetic_model, Engine, Manifest, NativeModel};
use acpc::trace::{GeneratorConfig, ModelProfile, TraceGenerator};
use acpc::util::bench::{bench_scale, black_box, Bench, BenchJson};

fn main() {
    let smoke = bench_scale() == "smoke";

    // Native kernel on synthetic weights at the production TCN geometry
    // (window 16, 32 channels, dilations 1/2/4): artifact-free, so this
    // case lands in the perf trajectory on every CI run.
    let batch = 256usize;
    let window = 16usize;
    let (mm, store) = synthetic_model("tcn", window, FEATURE_DIM, 32, &[1, 2, 4], 0xBE7C);
    let mut native = NativeModel::from_params(&mm, &store).unwrap();
    let x = vec![0.3f32; batch * window * FEATURE_DIM];
    let mut out: Vec<f32> = Vec::new();
    let bench = Bench::new(3, if smoke { 10 } else { 40 }).throughput(batch as u64);
    let res = bench.run("native_tcn_infer", || {
        native.predict_into(&x, batch, &mut out);
        black_box(out[0]);
    });
    let mut json = BenchJson::new("predictor_latency");
    json.push(&res);
    match json.write() {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => acpc::log_warn!("predictor_latency: could not write trajectory: {e}"),
    }

    let Some(dir) = acpc::runtime::artifacts_dir() else {
        acpc::log_warn!(
            "predictor_latency: artifacts/ missing — PJRT comparison skipped (run `make artifacts`)"
        );
        return;
    };
    let manifest = Manifest::load(&dir).unwrap();
    let engine = Engine::cpu().unwrap();

    // Feature extraction rate.
    let gcfg = GeneratorConfig::new(ModelProfile::gpt3ish(), 3);
    let geom = GeometryHints::from_generator(&gcfg);
    let trace = TraceGenerator::new(gcfg).generate(200_000);
    let window = manifest.model("tcn").unwrap().window;
    let bench = Bench::new(1, 5).throughput(trace.len() as u64);
    bench.run("feature_extractor.push", || {
        let mut fx = FeatureExtractor::new(window, geom);
        let mut seq = vec![0.0f32; window * acpc::predictor::FEATURE_DIM];
        for a in &trace {
            fx.push(a, &mut seq);
            black_box(seq[0]);
        }
    });

    // Model inference, both backends, at the PJRT AOT batch size (the
    // shape that maximally favors PJRT — no tail padding).
    for name in manifest.models.keys() {
        let mut rt = ModelRuntime::load(&engine, &manifest, name).unwrap();
        let b = rt.infer_batch;
        let row = rt.row_elems();
        let x = vec![0.3f32; b * row];
        let bench = Bench::new(2, 10).throughput(b as u64);
        let nat = bench.run(&format!("{name}.predict.native[b={b}]"), || {
            black_box(rt.predict(&x, b));
        });
        rt.set_backend(Backend::Pjrt);
        let pjrt = bench.run(&format!("{name}.predict.pjrt[b={b}]"), || {
            black_box(rt.predict(&x, b));
        });
        println!(
            "{name}: native {:.0} ns/row vs pjrt {:.0} ns/row — {:.2}x per-row speedup",
            nat.mean_ns / b as f64,
            pjrt.mean_ns / b as f64,
            pjrt.mean_ns / nat.mean_ns
        );
    }

    // Train step latency (online-learning budget; Adam stays in XLA).
    for name in ["tcn", "dnn"] {
        let mut rt = ModelRuntime::load(&engine, &manifest, name).unwrap();
        let b = rt.mm.train.batch;
        let row = rt.row_elems();
        let x = vec![0.3f32; b * row];
        let y = vec![1.0f32; b];
        let bench = Bench::new(1, 5).throughput(b as u64);
        bench.run(&format!("{name}.train_step[b={b}]"), || {
            black_box(rt.train_step(x.clone(), y.clone()).unwrap());
        });
    }
}
