//! Quickstart: the smallest end-to-end ACPC run, through the library's one
//! front door — build a `RunSpec`, hand it to a `Runner`, read the
//! `RunReport`.
//!
//! Simulates the L2 under plain LRU and under ACPC (heuristic predictor —
//! no artifacts needed) on the same GPT-style inference trace and prints
//! the paper's core comparison: hit rate up, pollution down.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use acpc::api::{RunSpec, Runner};
use acpc::config::PredictorKind;

fn main() -> anyhow::Result<()> {
    let accesses = 400_000;

    // 1. Baseline: LRU, no learned guidance.
    let lru_spec = RunSpec::builder()
        .policy("lru")
        .predictor(PredictorKind::None)
        .accesses(accesses)
        .build()?;
    let lru = Runner::new(lru_spec)?.run()?;

    // 2. ACPC: priority-aware replacement + prefetch filtering, driven by a
    //    reuse predictor (the built-in heuristic here; swap in the trained
    //    TCN with `.predictor(PredictorKind::Tcn)` once `make artifacts`
    //    has run — the runner falls back to the heuristic when artifacts
    //    are absent and records it in `predictor_effective`).
    let acpc_spec = RunSpec::builder()
        .policy("acpc")
        .predictor(PredictorKind::Heuristic)
        .accesses(accesses)
        .build()?;
    let acpc = Runner::new(acpc_spec)?.run()?;

    println!("workload: {} accesses, {} tokens decoded", accesses, acpc.result.tokens);
    println!("  LRU : {}", lru.result.report.summary());
    println!("  ACPC: {}", acpc.result.report.summary());
    println!(
        "\nACPC vs LRU: hit rate {:+.1} pp, pollution {:+.1}%, AMAT {:+.1}%",
        (acpc.result.report.l2_hit_rate - lru.result.report.l2_hit_rate) * 100.0,
        (acpc.result.report.l2_pollution_ratio / lru.result.report.l2_pollution_ratio - 1.0)
            * 100.0,
        (acpc.result.report.amat / lru.result.report.amat - 1.0) * 100.0,
    );
    // Every report embeds its fully-resolved spec: save it and re-run it
    // with `acpc run --spec` to reproduce this exact experiment.
    println!("\nreproducible spec:\n{}", acpc.spec.to_json().to_pretty());
    assert!(
        acpc.result.report.l2_hit_rate > lru.result.report.l2_hit_rate,
        "ACPC should win"
    );
    Ok(())
}
