//! Policy shoot-out across the whole zoo — every replacement policy in the
//! library on the same GPT-style trace, including the Belady upper bound,
//! run in parallel on the thread pool. Each run is one `RunSpec` executed
//! through the unified `Runner`.
//!
//! ```bash
//! cargo run --release --example policy_comparison [accesses]
//! ```

use acpc::api::{RunReport, RunSpec, Runner};
use acpc::config::PredictorKind;
use acpc::util::bench::print_table;
use acpc::util::pool::{default_threads, run_parallel};

fn main() -> anyhow::Result<()> {
    let accesses: usize =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(500_000);

    let policies =
        ["random", "lru", "plru", "lip", "bip", "dip", "srrip", "brrip", "drrip", "ship",
         "mlpredict", "acpc", "belady"];

    let jobs: Vec<_> = policies
        .iter()
        .map(|&policy| {
            move || -> anyhow::Result<(&'static str, RunReport)> {
                let needs_pred = matches!(policy, "mlpredict" | "acpc");
                let kind =
                    if needs_pred { PredictorKind::Heuristic } else { PredictorKind::None };
                let spec = RunSpec::builder()
                    .policy(policy)
                    .predictor(kind)
                    .accesses(accesses)
                    .build()?;
                Ok((policy, Runner::new(spec)?.run()?))
            }
        })
        .collect();
    let results: Vec<(&'static str, RunReport)> =
        run_parallel(default_threads(), jobs).into_iter().collect::<anyhow::Result<_>>()?;

    let lru_report =
        results.iter().find(|(p, _)| *p == "lru").map(|(_, r)| r.result.report.clone()).unwrap();
    let mut rows: Vec<Vec<String>> = results
        .iter()
        .map(|(policy, r)| {
            vec![
                policy.to_string(),
                format!("{:.1}", r.result.report.l2_hit_rate * 100.0),
                format!("{:.2}", r.result.report.l2_pollution_ratio * 100.0),
                r.result
                    .report
                    .miss_penalty_reduction_vs(&lru_report)
                    .map(|v| format!("{v:+.1}"))
                    .unwrap_or_else(|| "n/a".into()),
                format!("{:.2}", r.result.report.amat),
                format!("{:.2}", r.result.emu),
                format!("{:.2}M", r.result.accesses_per_sec / 1e6),
            ]
        })
        .collect();
    rows.sort_by(|a, b| b[1].parse::<f64>().unwrap().total_cmp(&a[1].parse::<f64>().unwrap()));
    print_table(
        "All policies, GPT-style trace",
        &["policy", "CHR %", "PPR %", "MPR vs LRU %", "AMAT", "EMU", "sim acc/s"],
        &rows,
    );
    println!("\n(belady is the clairvoyant upper bound; mlpredict/acpc use the heuristic predictor here)");
    Ok(())
}
