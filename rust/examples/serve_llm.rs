//! End-to-end serving-node driver (DESIGN.md's end-to-end validation
//! example): loads the *real trained* TCN artifact via PJRT, stands up the
//! multi-worker serving coordinator (router + dynamic batcher + predictor
//! service), admits a stream of inference sessions against the ACPC-managed
//! hierarchy, and reports throughput + latency percentiles — then repeats
//! with plain LRU for contrast.
//!
//! Before serving, the same comparison runs once in batch mode through the
//! unified `Runner` (the library's front door): the batch-sim prediction of
//! the ACPC-vs-LRU win should agree in sign with what the serving
//! coordinator then measures.
//!
//! Requires `make artifacts`. A short training pass runs first so the TCN
//! predicts meaningfully (all from rust via the compiled train step).
//!
//! ```bash
//! cargo run --release --example serve_llm
//! ```

use acpc::api::{RunSpec, Runner};
use acpc::config::PredictorKind;
use acpc::coordinator::{serve, RouterPolicy, ServeConfig};
use acpc::predictor::{Dataset, GeometryHints, ModelRuntime, PredictorBox};
use acpc::runtime::{Engine, Manifest};
use acpc::trace::{GeneratorConfig, ModelProfile, TraceGenerator};
use acpc::training::{train, TrainConfig};
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let Some(dir) = acpc::runtime::artifacts_dir() else {
        acpc::log_error!("serve_llm: run `make artifacts` first");
        std::process::exit(1);
    };
    let manifest = Manifest::load(&dir).expect("manifest");
    let window = manifest.model("tcn").expect("tcn").window;

    // --- quick training pass (rust-driven, compiled Adam step) ------------
    println!("[1/4] training TCN predictor (short run) ...");
    let seed = 0x5E2E;
    let gcfg_train = GeneratorConfig::new(ModelProfile::gpt3ish(), seed);
    let geom = GeometryHints::from_generator(&gcfg_train);
    let trace = TraceGenerator::new(gcfg_train).generate(400_000);
    let ds = Dataset::build(&trace, window, geom, 4096, 6);
    let split = ds.split(seed);
    let engine = Engine::cpu().expect("engine");
    let mut tcn = ModelRuntime::load(&engine, &manifest, "tcn").expect("tcn");
    let res = train(
        &mut tcn,
        &ds,
        &split,
        &TrainConfig { epochs: 12, patience: 0, max_batches_per_epoch: 40, seed, verbose_every: 4 },
    );
    println!("      trained: loss {:.3} → {:.3}", res.train_curve[0], res.final_train_loss);
    // Keep the trained weights for the serving run (checkpoint via tempdir).
    let ckpt = std::env::temp_dir().join("acpc_serve_llm.ckpt");
    tcn.store.save_checkpoint(&ckpt).expect("checkpoint");
    drop(tcn);

    // --- batch-mode cross-check through the Runner ------------------------
    println!("[2/4] batch-sim cross-check (ACPC+TCN vs LRU, unified Runner) ...");
    let batch_spec = |policy: &str, kind: PredictorKind| -> anyhow::Result<RunSpec> {
        RunSpec::builder().policy(policy).predictor(kind).accesses(300_000).seed(seed).build()
    };
    let load_trained = |engine: &Engine| {
        let mut rt = ModelRuntime::load(engine, &manifest, "tcn").expect("tcn");
        rt.store.load_checkpoint(&ckpt).expect("load trained weights");
        PredictorBox::Model(Box::new(rt))
    };
    let acpc_batch = Runner::new(batch_spec("acpc", PredictorKind::Tcn)?)?
        .with_predictor(load_trained(&engine))
        .run()?;
    let lru_batch = Runner::new(batch_spec("lru", PredictorKind::None)?)?.run()?;
    let batch_delta =
        (acpc_batch.result.report.l2_hit_rate - lru_batch.result.report.l2_hit_rate) * 100.0;
    println!("      batch-sim predicts: CHR {batch_delta:+.1} pp for ACPC+TCN over LRU");
    drop(engine);

    // --- serving runs -----------------------------------------------------
    let mk_cfg = |policy: &str| {
        let mut generator = GeneratorConfig::new(ModelProfile::gpt3ish(), 0xBEEF);
        generator.arrival_p_hot = 0.0;
        generator.arrival_p_cold = 0.0;
        ServeConfig {
            workers: 4,
            policy: policy.into(),
            hierarchy: acpc::mem::HierarchyConfig::scaled(),
            generator,
            total_sessions: 96,
            arrival_interval: Duration::from_micros(50),
            router: RouterPolicy::LeastLoaded,
            predict_batch: 256,
            predict_deadline: Duration::from_millis(2),
            scenario: None,
            adaptive: false,
            adapt: acpc::adapt::ControllerConfig::default(),
        }
    };

    println!("[3/4] serving with ACPC + trained TCN (4 workers) ...");
    let ckpt2 = ckpt.clone();
    let acpc_rep = serve(&mk_cfg("acpc"), window, move || {
        let dir = acpc::runtime::artifacts_dir().unwrap();
        let manifest = Manifest::load(&dir).unwrap();
        let engine = Engine::cpu().unwrap();
        let mut rt = ModelRuntime::load(&engine, &manifest, "tcn").unwrap();
        rt.store.load_checkpoint(&ckpt2).expect("load trained weights");
        PredictorBox::Model(Box::new(rt))
    });

    println!("[4/4] serving with LRU (no predictor) ...");
    let lru_rep = serve(&mk_cfg("lru"), 0, || PredictorBox::None);

    let show = |name: &str, r: &acpc::coordinator::ServeReport| {
        println!(
            "  {name:<12} tokens={:<6} tok/s(wall)={:<8.0} CHR={:.1}% PPR={:.2}% p50={:.0}ms p95={:.0}ms batches={} fill={:.0}",
            r.tokens,
            r.tokens_per_sec_wall,
            r.l2_hit_rate * 100.0,
            r.l2_pollution_ratio * 100.0,
            r.session_latency_ms_p50,
            r.session_latency_ms_p95,
            r.prediction_batches,
            r.mean_batch_fill,
        );
    };
    println!("\n== serving comparison ==");
    show("ACPC+TCN", &acpc_rep);
    show("LRU", &lru_rep);
    let serve_delta = (acpc_rep.l2_hit_rate - lru_rep.l2_hit_rate) * 100.0;
    println!(
        "\nsimulated-memory win: CHR {:+.1} pp (batch-sim predicted {:+.1} pp), pollution {:+.0}%",
        serve_delta,
        batch_delta,
        (acpc_rep.l2_pollution_ratio / lru_rep.l2_pollution_ratio - 1.0) * 100.0
    );
    std::fs::remove_file(ckpt).ok();
    Ok(())
}
