//! Online adaptation (§3.4): the workload's Zipf head rotates mid-run
//! ("phase drift"), and we compare ACPC+TCN with the online feedback loop
//! ON vs OFF. With feedback, the predictor retrains on observed reuse
//! outcomes (replay buffer + compiled Adam steps from rust) and recovers;
//! without it, predictions go stale.
//!
//! Both arms execute through the unified `Runner`; the pre-trained model
//! (checkpointed weights) is injected with `Runner::with_predictor`, the
//! API's escape hatch for caller-owned predictors.
//!
//! Requires `make artifacts`.
//!
//! ```bash
//! cargo run --release --example online_adaptation
//! ```

use acpc::api::{RunSpec, Runner};
use acpc::config::PredictorKind;
use acpc::predictor::{Dataset, GeometryHints, ModelRuntime, PredictorBox};
use acpc::runtime::{Engine, Manifest};
use acpc::trace::{GeneratorConfig, ModelProfile, TraceGenerator};
use acpc::training::{train, TrainConfig};

fn main() -> anyhow::Result<()> {
    let Some(dir) = acpc::runtime::artifacts_dir() else {
        acpc::log_error!("online_adaptation: run `make artifacts` first");
        std::process::exit(1);
    };
    let manifest = Manifest::load(&dir).expect("manifest");
    let engine = Engine::cpu().expect("engine");
    let window = manifest.model("tcn").expect("tcn").window;
    let seed = 0xADA7;

    // Pre-train on a *stationary* trace (no phase drift).
    println!("[1/3] pre-training TCN on a drift-free trace ...");
    let mut gcfg = GeneratorConfig::new(ModelProfile::gpt3ish(), seed);
    gcfg.phase_period = 0; // stationary
    let geom = GeometryHints::from_generator(&gcfg);
    let trace = TraceGenerator::new(gcfg).generate(400_000);
    let ds = Dataset::build(&trace, window, geom, 4096, 6);
    let split = ds.split(seed);
    let mut pretrained = ModelRuntime::load(&engine, &manifest, "tcn").expect("tcn");
    let res = train(
        &mut pretrained,
        &ds,
        &split,
        &TrainConfig { epochs: 10, patience: 0, max_batches_per_epoch: 40, seed, verbose_every: 0 },
    );
    println!("      pre-trained loss: {:.3}", res.final_train_loss);
    let ckpt = std::env::temp_dir().join("acpc_online_adapt.ckpt");
    pretrained.store.save_checkpoint(&ckpt).expect("ckpt");

    // Evaluation spec WITH aggressive phase drift; `feedback` selects the
    // §3.4 interval-retrain loop.
    let mk_spec = |feedback: usize| -> anyhow::Result<RunSpec> {
        RunSpec::builder()
            .name(&format!("drift-feedback{feedback}"))
            .policy("acpc")
            .predictor(PredictorKind::Tcn)
            .accesses(600_000)
            .phase_period(1_500) // rotate the hot set frequently
            .feedback_interval(feedback)
            .seed(seed)
            .build()
    };
    let load = |engine: &Engine| {
        let mut rt = ModelRuntime::load(engine, &manifest, "tcn").expect("tcn");
        rt.store.load_checkpoint(&ckpt).expect("load");
        PredictorBox::Model(Box::new(rt))
    };

    println!("[2/3] drifting workload, feedback OFF ...");
    let off = Runner::new(mk_spec(0)?)?.with_predictor(load(&engine)).run()?;

    println!("[3/3] drifting workload, feedback ON (retrain every 50k accesses) ...");
    let on = Runner::new(mk_spec(50_000)?)?.with_predictor(load(&engine)).run()?;

    println!("\n== online adaptation under phase drift ==");
    println!(
        "  feedback OFF: {} (online steps: {})",
        off.result.report.summary(),
        off.result.online_train_steps
    );
    println!(
        "  feedback ON : {} (online steps: {})",
        on.result.report.summary(),
        on.result.online_train_steps
    );
    println!(
        "\nadaptation gain: CHR {:+.2} pp, pollution {:+.1}%",
        (on.result.report.l2_hit_rate - off.result.report.l2_hit_rate) * 100.0,
        (on.result.report.l2_pollution_ratio / off.result.report.l2_pollution_ratio - 1.0)
            * 100.0
    );
    std::fs::remove_file(ckpt).ok();
    Ok(())
}
